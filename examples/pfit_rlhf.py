"""PFIT end-to-end driver (paper §IV-C + Fig. 4): federated RLHF with the
double reward model, personalized reward functions, last-2-layer sparse
updates, PPO local optimization, masked aggregation over a Rayleigh uplink.

    PYTHONPATH=src python examples/pfit_rlhf.py --method pfit --rounds 20
"""
import argparse
import json

from repro.core.pfit import METHODS, PFITConfig, run_pfit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="pfit", choices=METHODS)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--sparsity", type=float, default=0.4)
    ap.add_argument("--snr-db", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    res = run_pfit(PFITConfig(
        method=args.method, rounds=args.rounds, n_clients=args.clients,
        sparsity=args.sparsity, snr_db=args.snr_db, seed=args.seed,
        verbose=True))
    print(json.dumps({k: v for k, v in res.items()
                      if k != "reward_per_round"}, indent=2))
    print("reward curve:", [round(r, 4) for r in res["reward_per_round"]])


if __name__ == "__main__":
    main()
