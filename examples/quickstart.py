"""Quickstart: build any assigned architecture (reduced), train it a few
steps, then serve a few tokens — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py --arch tinyllama-1.1b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.configs import get_config, list_configs
from repro.models import Model
from repro.optim import adamw
from repro.sharding import MeshCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list_configs())
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"params≈{cfg.param_count():,}")
    model = Model(cfg, meshctx=MeshCtx.single_device())
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    opt = adamw(3e-3)
    opt_state = opt.init(params)
    rng = np.random.RandomState(0)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, g = jax.value_and_grad(lambda p: model.lm_loss(p, batch))(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return trees.tree_add(params, upd), opt_state, loss

    B, S = 8, 64
    for i in range(args.steps):
        toks = jnp.asarray(rng.randint(6, 100, size=(B, S + 1)))
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "mask": jnp.ones((B, S))}
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.asarray(
                rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.n_prefix_tokens:
            batch["patches"] = jnp.asarray(
                rng.randn(B, cfg.n_prefix_tokens, cfg.prefix_dim), jnp.float32)
        params, opt_state, loss = train_step(params, opt_state, batch)
        if i % 5 == 0:
            print(f"step {i:3d} loss {float(loss):.4f}")

    if not cfg.is_encoder_only:
        kw = {}
        if cfg.is_encoder_decoder:
            kw["frames"] = batch["frames"][:1]
        if cfg.n_prefix_tokens:
            kw["patches"] = batch["patches"][:1]
        prompt = batch["tokens"][:1, :16]
        logits, cache = model.prefill(params, prompt, cache_len=32, **kw)
        toks = []
        for _ in range(8):
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(int(nxt[0, 0]))
            logits, cache = model.decode_step(params, cache, nxt)
        print("greedy decode:", toks)


if __name__ == "__main__":
    main()
