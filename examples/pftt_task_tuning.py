"""PFTT end-to-end driver (paper §IV-D + Fig. 5): 4 clients, Dirichlet
non-IID AG-News-like data, RoBERTa backbone, universal adapters aggregated
over a Rayleigh uplink, local LoRA personalization.

    PYTHONPATH=src python examples/pftt_task_tuning.py --method pftt --rounds 40
"""
import argparse
import json

from repro.core.pftt import METHODS, PFTTConfig, run_pftt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="pftt", choices=METHODS)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--snr-db", type=float, default=5.0)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    res = run_pftt(PFTTConfig(
        method=args.method, rounds=args.rounds, n_clients=args.clients,
        snr_db=args.snr_db, local_steps=args.local_steps, seed=args.seed,
        verbose=True))
    print(json.dumps({k: v for k, v in res.items()
                      if k != "acc_per_round"}, indent=2))
    print("accuracy curve:", [round(a, 3) for a in res["acc_per_round"]])


if __name__ == "__main__":
    main()
