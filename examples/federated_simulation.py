"""Paper §V simulation: 4 clients + server, Rayleigh channel @ 5 dB,
40 communication rounds — runs BOTH proposed methods and all baselines,
printing the Fig. 4 / Fig. 5 comparison tables.

    PYTHONPATH=src python examples/federated_simulation.py --quick
"""
import argparse
import json

from repro.core.pfit import PFITConfig, run_pfit
from repro.core.pftt import PFTTConfig, run_pftt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds for a fast demonstration")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rounds_t = 10 if args.quick else 40
    rounds_i = 6 if args.quick else 20

    results = {"pftt": {}, "pfit": {}}
    print("=== PFTT (Fig. 5): accuracy / communication ===")
    for method in ("pftt", "vanilla_fl", "fedbert", "fedlora"):
        r = run_pftt(PFTTConfig(method=method, rounds=rounds_t))
        results["pftt"][method] = r
        print(f"{method:12s} acc={r['final_acc']:.3f} "
              f"bytes/round={r['mean_round_bytes']:,.0f} "
              f"delay/round={r['mean_round_delay_s']:.3f}s")

    print("\n=== PFIT (Fig. 4): reward / communication ===")
    for method in ("pfit", "sfl", "pfl", "shepherd"):
        r = run_pfit(PFITConfig(method=method, rounds=rounds_i))
        results["pfit"][method] = r
        print(f"{method:12s} reward={r['final_reward']:.4f} "
              f"bytes/round={r['mean_round_bytes']:,.0f}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
