"""Paper Fig. 5: PFTT vs vanilla FL / FedBERT / FedLoRA — accuracy (left)
and per-round communication delay over the Rayleigh uplink (right)."""
from __future__ import annotations

import json
import os

from repro.core.pftt import PFTTConfig, run_pftt


def main(rounds: int = 40, quick: bool = False, out: str = None):
    if quick:
        rounds = 8
    results = {}
    for method in ("pftt", "vanilla_fl", "fedbert", "fedlora"):
        cfg = PFTTConfig(method=method, rounds=rounds,
                         pretrain_steps=120 if quick else 250)
        results[method] = run_pftt(cfg)
        r = results[method]
        print(f"fig5 {method:10s} acc={r['final_acc']:.3f} "
              f"bytes/round={r['mean_round_bytes']:,.0f} "
              f"delay/round={r['mean_round_delay_s']:.4f}s")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(results, f, indent=1, default=float)
    return results


if __name__ == "__main__":
    main()
