"""Ablations beyond the paper's figures.

* PFIT sparsity sweep — reward / upload bytes vs head-sparsity ∈ {0, .2, .4, .6}
  (the paper only reports 20 % and 40 %); exposes the personalization-vs-
  communication trade-off the paper discusses in §VI-2/3.
* PFTT capacity sweep — accuracy vs (adapter_dim, lora_rank); shows the
  adapters-global/LoRA-local split is robust across budgets.
* PFTT SNR sweep — accuracy vs mean uplink SNR ∈ {0, 5, 10} dB (outage rate
  falls with SNR; accuracy tracks it).

    PYTHONPATH=src python -m benchmarks.ablations [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.pfit import PFITConfig, run_pfit
from repro.core.pftt import PFTTConfig, run_pftt


def pfit_sparsity_sweep(rounds=8, quick=True):
    rows = []
    for sp in (0.0, 0.2, 0.4, 0.6):
        r = run_pfit(PFITConfig(method="pfit", sparsity=sp, rounds=rounds,
                                pretrain_steps=120 if quick else 250,
                                rm_steps=120 if quick else 250))
        rows.append({"sparsity": sp, "reward": r["final_reward"],
                     "bytes": r["mean_round_bytes"]})
        print(f"ablation pfit sparsity={sp:.1f} reward={r['final_reward']:.4f} "
              f"bytes/rnd={r['mean_round_bytes']:,.0f}")
    return rows


def pftt_capacity_sweep(rounds=10, quick=True):
    rows = []
    for ad, lr_ in ((4, 4), (8, 8), (16, 16)):
        r = run_pftt(PFTTConfig(method="pftt", adapter_dim=ad, lora_rank=lr_,
                                rounds=rounds,
                                pretrain_steps=120 if quick else 250))
        rows.append({"adapter_dim": ad, "lora_rank": lr_,
                     "acc": r["final_acc"], "bytes": r["mean_round_bytes"]})
        print(f"ablation pftt adapter={ad} rank={lr_} acc={r['final_acc']:.3f} "
              f"bytes/rnd={r['mean_round_bytes']:,.0f}")
    return rows


def pftt_snr_sweep(rounds=10, quick=True):
    rows = []
    for snr in (0.0, 5.0, 10.0):
        r = run_pftt(PFTTConfig(method="pftt", snr_db=snr, rounds=rounds,
                                pretrain_steps=120 if quick else 250))
        rows.append({"snr_db": snr, "acc": r["final_acc"],
                     "delay_s": r["mean_round_delay_s"]})
        print(f"ablation pftt snr={snr:.0f}dB acc={r['final_acc']:.3f} "
              f"delay/rnd={r['mean_round_delay_s']:.4f}s")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--out", default="experiments/ablations.json")
    args, _ = ap.parse_known_args()
    res = {
        "pfit_sparsity": pfit_sparsity_sweep(quick=args.quick),
        "pftt_capacity": pftt_capacity_sweep(quick=args.quick),
        "pftt_snr": pftt_snr_sweep(quick=args.quick),
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1, default=float)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
