"""Run-telemetry overhead: the observability acceptance pin.

Telemetry (``repro.obs``) must be effectively free: the acceptance gate
is <2% round wall-clock overhead at population scale (10k-client store,
64-client sampled cohorts — the same workload ``population_bench``
pins), with the fused engine still doing exactly ONE dispatch per round
and ZERO dense merges while the on-device health scalars ride along.

Measurement: steady-state round wall — the runner's per-round round
span (``round_wall``; sample+plan+gather+device-step+scatter+ledger,
which contains every in-round telemetry cost: span bookkeeping, Chrome
event recording, and the in-body health reductions), round 0 (compile)
excluded, MINIMUM over the post-compile rounds of ALTERNATED off/on/
off/on runs — scheduler noise is one-sided additive (min is the classic
low-variance estimator of the true steady cost) and alternation cancels
the slow process-level drift that otherwise swamps a 2% gate when one
side runs entirely before the other.  The per-round JSONL
emission (``tele.round_event``, the one cost that lands outside the
round span) is microbenched directly and added to the ON side.  Eval is
excluded from both sides (same compiled eval program either way), which
only shrinks the denominator — the reported fraction is conservative.
Dispatch count is read from the run's OWN trace artifact (one
``device-step`` span per round) and dense merges from
``peft.dense_merge_count()`` (trace-time counter: zero delta over the
run proves the compiled program contains no merged weights).

    PYTHONPATH=src python -m benchmarks.run --only obs      # quick
    FULL=1 PYTHONPATH=src python -m benchmarks.obs_overhead_bench
"""
from __future__ import annotations

import json
import os
import time

POP_N, COHORT_K = 10_000, 64


def _pftt_kw(**over):
    kw = dict(local_steps=3, batch=4, pretrain_steps=10,
              samples_per_client=32, test_samples=8, d_model=32,
              lora_rank=2, adapter_dim=4, seed=0, verbose=False)
    kw.update(over)
    return kw


def _run(rounds: int, tele_dir: str | None) -> dict:
    from repro.core.pftt import PFTTConfig, run_pftt
    from repro.fl.population import PopulationConfig
    from repro.obs import TelemetryConfig
    from repro.wireless.scenarios import Scenario

    pop = PopulationConfig(
        population=POP_N, cohort_size=COHORT_K, sampler="availability",
        scenario=Scenario(alpha=0.1, avail="diurnal", avail_period=24,
                          mobility="waypoint", seed=1))
    tele = (TelemetryConfig(out_dir=tele_dir, trace=True, health=True)
            if tele_dir else None)
    t0 = time.perf_counter()
    res = run_pftt(PFTTConfig(population=pop, rounds=rounds, telemetry=tele,
                              **_pftt_kw()))
    return {"wall_s": time.perf_counter() - t0,
            "round_wall": res["round_wall"],
            "final_acc": res["final_acc"]}


def _emit_cost_s(tmpdir: str, n: int = 200) -> float:
    """Median seconds per JSONL round-event append (open+write+fsync) —
    the one per-round telemetry cost outside the runner's round span."""
    import numpy as np

    from repro.obs import HEALTH_KEYS, RunTelemetry

    tele = RunTelemetry(os.path.join(tmpdir, "emit"))
    tele.start({"mode": "emit-microbench"})
    data = {"acc": 0.5, "cohort": list(range(COHORT_K)),
            "comm": {"record_id": 0, "round": 0, "bytes": 1e5,
                     "delay_s": 0.05, "energy_j": 1.0, "outages": 3},
            "staleness": {"pending": 2, "abandoned": 0,
                          "retransmissions": 1, "quorum_noops": 0},
            "health": {k: 0.123 for k in HEALTH_KEYS}}
    wall = {"phases": {"round": 0.4, "device-step": 0.35, "sample": 1e-4,
                       "gather": 3e-3, "scatter": 2e-3, "ledger": 1e-3}}
    ts = []
    for i in range(n):
        t0 = time.perf_counter()
        tele.round_event(i, data, wall=wall)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _overhead(quick: bool, tmpdir: str) -> dict:
    import numpy as np

    from repro.models import peft

    rounds = 8 if quick else 16

    m0 = peft.dense_merge_count()
    off_a = _run(rounds, None)
    on_a = _run(rounds, os.path.join(tmpdir, "warm"))
    off_b = _run(rounds, None)
    on_b = _run(rounds, os.path.join(tmpdir, "main"))
    dense_merges = peft.dense_merge_count() - m0
    emit_s = _emit_cost_s(tmpdir)

    # steady-state: drop each run's compile round, min over the union of
    # the alternated runs on each side
    off_walls = off_a["round_wall"][1:] + off_b["round_wall"][1:]
    on_walls = on_a["round_wall"][1:] + on_b["round_wall"][1:]
    off_med = float(np.min(off_walls))
    on_med = float(np.min(on_walls))
    row = {
        "population": POP_N, "cohort": COHORT_K, "rounds": rounds,
        "off_ms_per_round": 1e3 * off_med,
        "on_ms_per_round": 1e3 * (on_med + emit_s),
        "emit_ms_per_round": 1e3 * emit_s,
        "overhead_frac": (on_med + emit_s) / max(off_med, 1e-9) - 1.0,
        "round_wall_off": off_walls,
        "round_wall_on": on_walls,
        "dense_merges_with_health": int(dense_merges),
        "acc_off": off_b["final_acc"], "acc_on": on_b["final_acc"],
    }
    print(f"obs_overhead,{row['overhead_frac']:.4f},"
          f"{POP_N} clients cohort {COHORT_K}: "
          f"{row['off_ms_per_round']:.1f}ms/round off vs "
          f"{row['on_ms_per_round']:.1f}ms on "
          f"(jsonl emit {row['emit_ms_per_round']:.2f}ms)")
    return row


def _artifacts(tele_dir: str, rounds: int) -> dict:
    """Acceptance read from the ON run's own artifacts: schema-valid
    event stream, one device-step span per round."""
    from repro.launch.report import main as report_main
    from repro.obs import read_events, validate_events

    events = read_events(os.path.join(tele_dir, "events.jsonl"))
    errors = validate_events(events)
    n_rounds = sum(1 for e in events if e.get("event") == "round")
    with open(os.path.join(tele_dir, "trace.json")) as f:
        chrome = json.load(f)["traceEvents"]
    dispatches = sum(1 for e in chrome if e["name"] == "device-step")
    check_ok = report_main([tele_dir, "--check"]) == 0
    row = {
        "events": len(events), "round_events": n_rounds,
        "schema_errors": [str(e) for e in errors],
        "device_step_spans": dispatches,
        "dispatches_per_round": dispatches / max(rounds, 1),
        "report_check_ok": bool(check_ok),
    }
    print(f"obs_artifacts,{row['dispatches_per_round']:.2f},"
          f"{n_rounds} round events, {dispatches} device-step spans, "
          f"report --check {'OK' if check_ok else 'FAILED'}")
    return row


def main(quick: bool = True, out: str = "BENCH_obs.json"):
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        overhead = _overhead(quick, td)
        arts = _artifacts(os.path.join(td, "main"), overhead["rounds"])

    accept = {
        "overhead_frac": overhead["overhead_frac"],
        # the headline check_regression watches: ON/OFF round-wall ratio —
        # ~1.0 and stable, unlike the near-zero (noise-signed) frac
        "overhead_ratio": 1.0 + overhead["overhead_frac"],
        "overhead_lt_2pct": bool(overhead["overhead_frac"] < 0.02),
        "dispatches_per_round": arts["dispatches_per_round"],
        "one_dispatch_per_round":
            bool(arts["dispatches_per_round"] == 1.0),
        "dense_merges_with_health": overhead["dense_merges_with_health"],
        "zero_dense_merges":
            bool(overhead["dense_merges_with_health"] == 0),
        "schema_valid": not arts["schema_errors"],
        "acc_unchanged":
            bool(overhead["acc_off"] == overhead["acc_on"]),
    }
    for k, v in accept.items():
        print(f"# accept[{k}] = {v}")

    record = {"profile": "quick" if quick else "full",
              "workload": f"PFTT population mode ({POP_N}-client store, "
                          f"{COHORT_K}-client cohorts) with run telemetry "
                          "ON (JSONL events + Chrome trace + on-device "
                          "health scalars) vs OFF; steady-state per-round "
                          "wall = min over post-compile rounds of the "
                          "runner's round span + measured JSONL emit cost",
              "overhead": overhead,
              "artifacts": arts,
              "acceptance": accept}
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main(quick=not bool(os.environ.get("FULL")))
