"""Merged vs factored LoRA execution under the vmapped cohort engine.

The workload is the fedlora-shaped PFTT hot path: a frozen reduced-roberta
base, per-client trainable = rank-r LoRA factors, one fused vmapped round
step per round (``core/cohort.py``).  The MERGED path materializes
``W + (α/r)·A·B`` inside every loss evaluation, so vmap batches the merged
weights and every client carries a full per-client copy of every targeted
base weight; the FACTORED path (``peft.lora_proj``) threads the factors as
a side channel, keeping the base unbatched/broadcast.

Per cohort size (4, 16, 64) this reports, for both paths:
* wall-clock per fused round (same round count, compile-once),
* compiled peak memory (XLA ``memory_analysis``: temp + argument bytes),
* analytic per-round FLOPs (``launch.jaxpr_cost.step_flops``),
and a parity block: PFTT accuracy / PFIT(shepherd) reward curves of
factored vs the merged oracle over ≥3 rounds.  Writes
``BENCH_lora_path.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.configs import get_config
from repro.core.cohort import build_supervised_round
from repro.launch.jaxpr_cost import step_flops
from repro.models import Model
from repro.models import peft as peft_mod
from repro.optim import adamw
from repro.sharding import MeshCtx


# non-dense mixer families: same factored-vs-merged contrast through the
# MLA low-rank projections and the Mamba in/out projections (LM loss — these
# backbones have no cls head)
ARCH_ROWS = (
    ("deepseek-v2-236b",
     ("mixer/wq_a", "mixer/wq_b", "mixer/wkv_a", "mixer/wkv_b")),
    ("mamba2-1.3b", ("mixer/in_proj", "mixer/out_proj")),
)


def _build_workload(n_clients: int, *, arch="roberta-base", targets=None,
                    d_model=128, seq_len=16, batch=2, local_steps=3, rank=8,
                    seed=0):
    mcfg = get_config(arch).reduced(d_model=d_model, repeats=2)
    model = Model(mcfg, meshctx=MeshCtx.single_device())
    key = jax.random.PRNGKey(seed)
    params = model.init(key, max_seq=seq_len)
    peft_cfg = peft_mod.PEFTConfig(
        lora_rank=rank,
        lora_targets=targets
        or ("mixer/wq", "mixer/wk", "mixer/wv", "mixer/wo"))
    scale = peft_mod.lora_scale(peft_cfg)
    opt = adamw(1e-3, update_mask=lambda p: not p.endswith("/mask"))
    cls = mcfg.n_classes > 0 if hasattr(mcfg, "n_classes") else False

    def _loss(p, b, **kw):
        return model.cls_loss(p, b, **kw)[0] if cls \
            else model.lm_loss(p, b, **kw)

    def local_step_factored(tr, op, b):
        def loss_fn(t):
            return _loss(params, b, lora=t["lora"], lora_scale=scale)
        loss, g = jax.value_and_grad(loss_fn)(tr)
        upd, op = opt.update(g, op, tr)
        return trees.tree_add(tr, upd), op, loss

    def local_step_merged(tr, op, b):
        def loss_fn(t):
            eff = peft_mod.apply_lora(params, t["lora"], peft_cfg)
            return _loss(eff, b)
        loss, g = jax.value_and_grad(loss_fn)(tr)
        upd, op = opt.update(g, op, tr)
        return trees.tree_add(tr, upd), op, loss

    lora = peft_mod.init_lora(key, params, peft_cfg)
    tr = {"lora": lora}
    st_tr = trees.stack([tr] * n_clients)
    st_op = trees.stack([opt.init(tr)] * n_clients)
    rng = np.random.RandomState(seed)
    toks = rng.randint(6, mcfg.vocab_size,
                       (n_clients, local_steps, batch, seq_len))
    if cls:
        batches = {
            "tokens": jnp.asarray(toks, jnp.int32),
            "label": jnp.asarray(rng.randint(
                0, mcfg.n_classes, (n_clients, local_steps, batch)),
                jnp.int32)}
    else:
        batches = {
            "tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(np.roll(toks, -1, axis=-1), jnp.int32),
            "mask": jnp.ones((n_clients, local_steps, batch, seq_len),
                             jnp.float32)}
    weights = jnp.ones((n_clients,))
    return {"factored": local_step_factored, "merged": local_step_merged}, \
        st_tr, st_op, batches, weights


def _bench_path(local_step, st_tr, st_op, batches, weights, rounds: int):
    # donate=False: the same stacked state is reused across timing rounds
    # and by the other path, and the AOT-compiled program is inspectable
    round_step = build_supervised_round(local_step, donate=False)
    lowered = round_step.lower(st_tr, st_op, batches, weights)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    peak = int(mem.temp_size_in_bytes + mem.argument_size_in_bytes)
    flops = step_flops(lambda a, b, c: local_step(a, b, c)[0],
                       trees.unstack(st_tr, 1)[0],
                       trees.unstack(st_op, 1)[0],
                       jax.tree_util.tree_map(lambda x: x[0, 0], batches))
    # ^ per client per local step (abstract trace, no execution)
    out = round_step(st_tr, st_op, batches, weights)      # warmup (cached)
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    for _ in range(rounds):
        out = round_step(st_tr, st_op, batches, weights)
    jax.block_until_ready(out[0])
    return {"ms_per_round": (time.perf_counter() - t0) / rounds * 1e3,
            "peak_bytes": peak,
            "temp_bytes": int(mem.temp_size_in_bytes),
            "flops_per_client_step": int(flops)}


def _parity_block(full: bool):
    """Factored vs merged-oracle end-to-end curves (≥3 rounds, fp32).
    Quick profile checks PFTT; full adds the PFIT shepherd reward curve
    (reward-model training makes it ~a minute on this CPU)."""
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(n_clients=2, rounds=3, local_steps=2, pretrain_steps=10,
              samples_per_client=120, d_model=32, seed=0)
    acc_f = run_pftt(PFTTConfig(factored=True, **kw))["acc_per_round"]
    acc_m = run_pftt(PFTTConfig(factored=False, **kw))["acc_per_round"]
    block = {
        "pftt_acc_factored": acc_f, "pftt_acc_merged": acc_m,
        "pftt_max_abs_diff": float(np.abs(np.asarray(acc_f)
                                          - np.asarray(acc_m)).max()),
    }
    if not full:
        return block

    from repro.core.pfit import PFITConfig, run_pfit
    kw2 = dict(method="shepherd", n_clients=2, rounds=3, shepherd_steps=2,
               rollout_batch=4, pretrain_steps=10, rm_steps=10, d_model=48,
               n_layers=2, gen_len=8, prompt_len=6, seed=0)
    rew_f = run_pfit(PFITConfig(factored=True, **kw2))["reward_per_round"]
    rew_m = run_pfit(PFITConfig(factored=False, **kw2))["reward_per_round"]
    block.update({
        "pfit_shepherd_reward_factored": rew_f,
        "pfit_shepherd_reward_merged": rew_m,
        "pfit_max_abs_diff": float(np.abs(np.asarray(rew_f)
                                          - np.asarray(rew_m)).max()),
    })
    return block


def main(quick: bool = True, out: str = "BENCH_lora_path.json",
         parity: bool = True):
    cohorts = (4, 16) if quick else (4, 16, 64)
    rounds = 3 if quick else 10
    results = []
    for n in cohorts:
        steps, st_tr, st_op, batches, weights = _build_workload(n)
        row = {"n_clients": n}
        for name, ls in steps.items():
            row[name] = _bench_path(ls, st_tr, st_op, batches, weights,
                                    rounds)
        row["mem_ratio"] = row["merged"]["peak_bytes"] / \
            max(row["factored"]["peak_bytes"], 1)
        row["speedup"] = row["merged"]["ms_per_round"] / \
            max(row["factored"]["ms_per_round"], 1e-9)
        results.append(row)
        print(f"lora_path_factored_n{n},"
              f"{row['factored']['ms_per_round'] * 1e3:.1f},"
              f"merged={row['merged']['ms_per_round']:.1f}ms "
              f"peak {row['merged']['peak_bytes']:,}->"
              f"{row['factored']['peak_bytes']:,}B "
              f"(x{row['mem_ratio']:.2f}) speedup={row['speedup']:.2f}x")
    # non-dense mixer families at a fixed cohort: the factored win through
    # MLA's four low-rank projections and Mamba's in/out projections
    n_arch = 8
    for arch, targets in ARCH_ROWS:
        steps, st_tr, st_op, batches, weights = _build_workload(
            n_arch, arch=arch, targets=targets, d_model=64)
        row = {"arch": arch, "n_clients": n_arch, "lora_targets": list(targets)}
        for name, ls in steps.items():
            row[name] = _bench_path(ls, st_tr, st_op, batches, weights,
                                    rounds)
        row["mem_ratio"] = row["merged"]["peak_bytes"] / \
            max(row["factored"]["peak_bytes"], 1)
        row["speedup"] = row["merged"]["ms_per_round"] / \
            max(row["factored"]["ms_per_round"], 1e-9)
        results.append(row)
        print(f"lora_path_{arch}_n{n_arch},"
              f"{row['factored']['ms_per_round'] * 1e3:.1f},"
              f"merged={row['merged']['ms_per_round']:.1f}ms "
              f"peak {row['merged']['peak_bytes']:,}->"
              f"{row['factored']['peak_bytes']:,}B "
              f"(x{row['mem_ratio']:.2f}) speedup={row['speedup']:.2f}x")
    record = {"profile": "quick" if quick else "full",
              "workload": "fedlora-shaped PFTT round: frozen reduced "
                          "roberta d64 seq16 batch2, rank-4 LoRA on wq/wv, "
                          "fused vmapped round step, 3 local steps",
              "results": results}
    if parity:
        record["parity"] = _parity_block(full=not quick)
        msg = f"# parity: pftt max|dacc|={record['parity']['pftt_max_abs_diff']:.2e}"
        if "pfit_max_abs_diff" in record["parity"]:
            msg += f" pfit max|drew|={record['parity']['pfit_max_abs_diff']:.2e}"
        print(msg)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main(quick=not bool(os.environ.get("FULL")))
