"""Benchmark manifest: which benchmark writes which file, and the one
headline metric the CI regression check watches.

``HEADLINES`` maps benchmark name → (output file, dotted path into its
JSON, higher_is_better).  ``benchmarks/run.py`` writes
``BENCH_manifest.json`` from it after a run (benchmark → file → realized
headline value), and ``benchmarks/check_regression.py`` re-extracts the
same path from the committed reference files — so neither CI nor the
checker hardcodes file names.

The headline is a RATIO (speedup, memory ratio, reduction factor) or a
bounded fraction, not a raw wall-clock: CI machines are noisy, ratios of
two timings taken on the same box mostly cancel the noise.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

# name → (file, dotted path — list indices as bare ints, higher_is_better)
HEADLINES = {
    "fl_engine": ("BENCH_fl_engine.json", "results.0.speedup", True),
    "lora_path": ("BENCH_lora_path.json", "results.0.mem_ratio", True),
    "cohort_shard": ("BENCH_cohort_shard.json",
                     "results.0.device_mem_ratio_8dev", True),
    "uplink": ("BENCH_uplink.json", "acceptance.int8_reduction_pftt", True),
    "straggler": ("BENCH_straggler.json", "results.1.throughput_ratio",
                  True),
    "deadline": ("BENCH_deadline.json", "acceptance.sim_time_ratio", True),
    "population": ("BENCH_population.json",
                   "acceptance.host_overhead_frac", False),
    "obs": ("BENCH_obs.json", "acceptance.overhead_ratio", False),
}

MANIFEST_FILE = "BENCH_manifest.json"


def extract(record: Dict, path: str):
    """Resolve a dotted path (``results.0.speedup``) into a JSON record."""
    cur = record
    for part in path.split("."):
        cur = cur[int(part)] if isinstance(cur, list) else cur[part]
    return cur


def headline_from_file(name: str, root: str = ".") -> Optional[Dict]:
    """The benchmark's manifest entry, read back from its output file
    (None when the file is missing or the path doesn't resolve — e.g. a
    bench that wasn't selected this run)."""
    import os
    file, path, higher = HEADLINES[name]
    fp = os.path.join(root, file)
    if not os.path.exists(fp):
        return None
    with open(fp) as f:
        record = json.load(f)
    try:
        value = extract(record, path)
    except (KeyError, IndexError, TypeError):
        return None
    return {"file": file, "metric": path, "value": float(value),
            "higher_is_better": higher}


def write_manifest(root: str = ".", out: str = MANIFEST_FILE) -> Dict:
    """Collect every resolvable headline into ``BENCH_manifest.json``."""
    import os
    entries = {}
    for name in HEADLINES:
        e = headline_from_file(name, root)
        if e is not None:
            entries[name] = e
    with open(os.path.join(root, out), "w") as f:
        json.dump(entries, f, indent=1)
    return entries
