"""FL cohort-engine benchmark: legacy looped per-client rounds vs the fused
vmapped round step (``core/cohort.py``), across cohort sizes.

The workload is the PFTT-shaped local objective (frozen reduced-roberta base,
trainable adapters + classifier head, AdamW) — the repo's FL hot path.  Per
round the legacy path issues ``n_clients × local_steps`` jitted dispatches
plus eager per-leaf aggregation ops; the engine issues ONE.  Emits
``name,us_per_call,derived`` CSV rows and writes the JSON record
(``BENCH_fl_engine.json``) that tracks the perf trajectory across PRs.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.configs import get_config
from repro.core.aggregation import fedavg
from repro.core.cohort import build_supervised_round
from repro.models import Model
from repro.models import peft as peft_mod
from repro.optim import adamw
from repro.sharding import MeshCtx
from repro.wireless import RayleighChannel


def _build_workload(n_clients: int, *, d_model=16, seq_len=16, batch=2,
                    local_steps=5, seed=0):
    mcfg = get_config("roberta-base").reduced(d_model=d_model, repeats=2)
    model = Model(mcfg, meshctx=MeshCtx.single_device())
    key = jax.random.PRNGKey(seed)
    peft_cfg = peft_mod.PEFTConfig(adapter_dim=8,
                                   lora_targets=("mixer/wq", "mixer/wv"))
    params = peft_mod.init_adapters(key, model.init(key), mcfg, peft_cfg)
    pred = lambda p: peft_mod.is_adapter_path(p) or p.startswith("cls_head")

    opt = adamw(1e-3)

    def local_step(tr, op, b):
        def loss_fn(t):
            return model.cls_loss(trees.merge(params, t), b)[0]
        loss, g = jax.value_and_grad(loss_fn)(tr)
        upd, op = opt.update(g, op, tr)
        return trees.tree_add(tr, upd), op, loss

    trainable = trees.select(params, pred)
    states = [(trainable, opt.init(trainable)) for _ in range(n_clients)]

    rng = np.random.RandomState(seed)
    tokens = rng.randint(0, mcfg.vocab_size,
                         (n_clients, local_steps, batch, seq_len))
    labels = rng.randint(0, mcfg.n_classes, (n_clients, local_steps, batch))
    batches = {"tokens": tokens.astype(np.int32),
               "label": labels.astype(np.int32)}
    weights = RayleighChannel(seed=seed).outage_weights(
        np.random.RandomState(seed + 1).exponential(1.0, n_clients))
    if weights.sum() == 0:
        weights[0] = 1.0
    return local_step, pred, states, batches, weights, local_steps


def _run_loop_round(local_step_jit, pred, states, batches, weights, steps,
                    counter):
    n = len(states)
    for ci in range(n):
        tr, op = states[ci]
        for s in range(steps):
            b = {k: jnp.asarray(v[ci, s]) for k, v in batches.items()}
            tr, op, _ = local_step_jit(tr, op, b)
            counter[0] += 1
        states[ci] = (tr, op)
    alive = [ci for ci in range(n) if weights[ci] > 0]
    if alive:
        agg = fedavg([trees.select(states[ci][0], pred) for ci in alive])
        counter[0] += 1
        states[:] = [(trees.merge(tr, agg), op) for tr, op in states]
    jax.block_until_ready([tr for tr, _ in states])
    return states


def bench_cohort(n_clients: int, *, rounds=3, **kw):
    local_step, pred, states, batches, weights, steps = _build_workload(
        n_clients, **kw)

    # --- legacy: one jitted dispatch per client per local step
    local_step_jit = jax.jit(local_step)
    counter = [0]
    loop_states = list(states)
    _run_loop_round(local_step_jit, pred, loop_states, batches, weights,
                    steps, counter)                       # warmup/compile
    loop_dispatches = counter[0]
    t0 = time.perf_counter()
    for _ in range(rounds):
        _run_loop_round(local_step_jit, pred, loop_states, batches, weights,
                        steps, counter)
    loop_s = (time.perf_counter() - t0) / rounds

    # --- fused: vmap(clients) x scan(local steps) + stacked aggregation,
    # donated stacked state -> ONE dispatch per round.  The per-round
    # host-stack + device transfer stays INSIDE the timed region so the
    # comparison charges both paths their real data-movement cost (the
    # engine path in run_pftt pays stack_host_batches every round).
    round_step = build_supervised_round(local_step, pred)
    st_tr = trees.stack([tr for tr, _ in states])
    st_op = trees.stack([op for _, op in states])
    w = jnp.asarray(weights)
    st_tr, st_op, _ = round_step(                               # warmup
        st_tr, st_op, {k: jnp.asarray(v) for k, v in batches.items()}, w)
    jax.block_until_ready(st_tr)
    t0 = time.perf_counter()
    for _ in range(rounds):
        dev_batches = {k: jnp.asarray(v) for k, v in batches.items()}
        st_tr, st_op, _ = round_step(st_tr, st_op, dev_batches, w)
    jax.block_until_ready(st_tr)
    fused_s = (time.perf_counter() - t0) / rounds

    return {"n_clients": n_clients, "local_steps": steps,
            "loop_ms_per_round": loop_s * 1e3,
            "fused_ms_per_round": fused_s * 1e3,
            "speedup": loop_s / fused_s,
            "dispatches_loop_per_round": loop_dispatches,
            "dispatches_fused_per_round": 1}


def main(quick: bool = True, out: str = "BENCH_fl_engine.json"):
    cohorts = (4, 16, 64)
    rounds = 3 if quick else 10
    results = []
    for n in cohorts:
        r = bench_cohort(n, rounds=rounds)
        results.append(r)
        print(f"fl_round_fused_n{n},{r['fused_ms_per_round'] * 1e3:.1f},"
              f"loop={r['loop_ms_per_round']:.1f}ms "
              f"speedup={r['speedup']:.2f}x "
              f"dispatches {r['dispatches_loop_per_round']}->1")
    record = {"profile": "quick" if quick else "full",
              "workload": "pftt-shaped adapters+head local SGD, "
                          "reduced roberta d16, batch 2, seq 16 "
                          "(dispatch-bound cohort-scaling regime)",
              "results": results}
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main(quick=not bool(os.environ.get("FULL")))
