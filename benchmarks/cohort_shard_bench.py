"""Sharded cohort engine: ONE fused federated round on 1 vs 8 devices.

The workload is the PFTT-shaped cohort of ``fl_engine_bench`` (frozen
reduced-roberta base, trainable adapters + head, AdamW, outage weight
vector).  Per cohort size this measures, for a single device and for an
8-way client-sharded mesh (``build_supervised_round(mesh=...)``,
``shard_map`` + psum aggregation — core/cohort.py):

* wall-clock per fused round (AOT-compiled, compile excluded),
* PER-DEVICE peak compiled memory (XLA ``memory_analysis``: temp +
  argument bytes — on the mesh each device only holds its client shard of
  trainables/moments/batches, so this shrinks with the shard count),

and writes ``BENCH_cohort_shard.json``.

Because ``--xla_force_host_platform_device_count`` must be set before jax
imports, each device count runs in a fresh worker subprocess (this module
with ``--worker``); the parent merges rows.  NOTE: 8 forced CPU devices
multiply compile time, and on an oversubscribed host the 8-way wall-clock
is pessimistic — treat the memory column as the scaling signal and the
wall-clock as an upper bound.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_MARK = "COHORT_SHARD_ROW "


# ---------------------------------------------------------------------------
# worker: runs under a forced device count, one row per cohort size
# ---------------------------------------------------------------------------


def _worker(cohorts, rounds: int) -> None:
    import jax
    import jax.numpy as jnp

    from repro import trees
    from repro.core.cohort import build_supervised_round
    from repro.sharding import cohort_sharding

    from benchmarks.fl_engine_bench import _build_workload

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None

    for n_clients in cohorts:
        local_step, pred, states, batches, weights, steps = _build_workload(
            n_clients)
        st_tr = trees.stack([tr for tr, _ in states])
        st_op = trees.stack([op for _, op in states])
        dev_batches = {k: jnp.asarray(v) for k, v in batches.items()}
        w = jnp.asarray(weights)
        cs = None
        if mesh is not None:
            cs = cohort_sharding(mesh, n_clients, ("data",))
            assert cs.n_pad == 0, (n_clients, n_dev)   # clean scaling points
            st_tr, st_op, dev_batches, w = jax.device_put(
                (st_tr, st_op, dev_batches, w), cs.named)
        # donate=False: state reused across timed rounds; AOT-compile so the
        # memory stats and the timed call share one executable
        round_step = build_supervised_round(
            local_step, pred, donate=False, mesh=mesh,
            client_axes=("data",) if mesh is not None else None)
        t0 = time.perf_counter()
        compiled = round_step.lower(st_tr, st_op, dev_batches, w).compile()
        compile_s = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        out = compiled(st_tr, st_op, dev_batches, w)          # warmup
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        for _ in range(rounds):
            out = compiled(st_tr, st_op, dev_batches, w)
        jax.block_until_ready(out[0])
        row = {"n_clients": n_clients, "n_devices": n_dev,
               "ms_per_round": (time.perf_counter() - t0) / rounds * 1e3,
               "device_peak_bytes": int(mem.temp_size_in_bytes
                                        + mem.argument_size_in_bytes),
               "temp_bytes": int(mem.temp_size_in_bytes),
               "argument_bytes": int(mem.argument_size_in_bytes),
               "compile_s": compile_s}
        print(_MARK + json.dumps(row), flush=True)


# ---------------------------------------------------------------------------
# parent: one subprocess per device count (XLA_FLAGS must precede jax import)
# ---------------------------------------------------------------------------


def _spawn(n_dev: int, cohorts, rounds: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", "")).strip()
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.cohort_shard_bench", "--worker",
         "--cohorts", ",".join(map(str, cohorts)), "--rounds", str(rounds)],
        capture_output=True, text=True, env=env, timeout=3600)
    rows = [json.loads(line[len(_MARK):]) for line in proc.stdout.splitlines()
            if line.startswith(_MARK)]
    if proc.returncode != 0 or len(rows) != len(cohorts):
        raise RuntimeError(
            f"cohort_shard worker (devices={n_dev}) failed "
            f"rc={proc.returncode}:\n{proc.stderr[-3000:]}")
    return rows


def main(quick: bool = True, out: str = "BENCH_cohort_shard.json"):
    cohorts = (8, 32) if quick else (8, 32, 64)
    rounds = 3 if quick else 10
    per_dev = {n_dev: _spawn(n_dev, cohorts, rounds) for n_dev in (1, 8)}
    results = []
    for i, n in enumerate(cohorts):
        r1, r8 = per_dev[1][i], per_dev[8][i]
        row = {"n_clients": n, "dev1": r1, "dev8": r8,
               "wallclock_speedup_8dev": r1["ms_per_round"]
               / max(r8["ms_per_round"], 1e-9),
               "device_mem_ratio_8dev": r1["device_peak_bytes"]
               / max(r8["device_peak_bytes"], 1)}
        results.append(row)
        print(f"cohort_shard_n{n},{r8['ms_per_round'] * 1e3:.1f},"
              f"1dev={r1['ms_per_round']:.1f}ms "
              f"speedup={row['wallclock_speedup_8dev']:.2f}x "
              f"device_peak {r1['device_peak_bytes']:,}->"
              f"{r8['device_peak_bytes']:,}B "
              f"(x{row['device_mem_ratio_8dev']:.2f})")
    record = {"profile": "quick" if quick else "full",
              "workload": "pftt-shaped adapters+head local SGD, reduced "
                          "roberta d16, batch 2, seq 16, 5 local steps; "
                          "fused round sharded over a (n_dev,) 'data' mesh "
                          "(forced host-platform CPU devices — wall-clock "
                          "is an upper bound, per-device memory is exact)",
              "results": results}
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--cohorts", default="8,32")
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()
    cohorts = tuple(int(c) for c in args.cohorts.split(","))
    if args.worker:
        _worker(cohorts, args.rounds)
    else:
        main(quick=not bool(os.environ.get("FULL")))
