"""Paper Table I reproduction: per-learning-stage parameter fraction and
per-round communication payload, computed from the real configs.

Stages: pre-training (all params), instruction tuning (PFIT: last-2 layers,
head-sparsity masked — paper band 5-10%), task tuning (PFTT: adapters+LoRA —
paper band 1-2%), RAG (no parameters)."""
from __future__ import annotations

import jax

from repro.configs import ASSIGNED, get_config
from repro.models import Model
from repro.models import peft as peft_mod
from repro.sharding import MeshCtx
from repro.wireless import tree_bytes
from repro import trees


def stage_fractions(arch: str, reduced: bool = True):
    cfg = get_config(arch)
    if reduced:
        # keep dims small (CPU) but layer counts realistic — the stage
        # fractions are layer-count driven
        cfg = cfg.reduced(repeats=12) if cfg.n_layers >= 12 else cfg.reduced(
            repeats=max(cfg.stages[0].repeats, 1))
    model = Model(cfg, meshctx=MeshCtx.single_device())
    params = model.init(jax.random.PRNGKey(0))
    total = trees.count_params(params)

    # instruction tuning (PFIT): last-2 layers × (1 - head sparsity on attn)
    lastk = peft_mod.last_k_layers_mask(params, cfg, 2)
    if not cfg.attention_free:
        hs = peft_mod.head_sparsity_mask(params, cfg, 0.4, seed=0)
        mask = jax.tree_util.tree_map(lambda a, b: a * b, lastk, hs)
    else:
        mask = lastk
    instr_bytes = tree_bytes(params, nonzero_mask=mask)
    instr_frac = instr_bytes / tree_bytes(params)

    # task tuning (PFTT): adapters (+ head) uploaded; LoRA stays local
    pc = peft_mod.PEFTConfig(lora_rank=8, adapter_dim=16)
    with_ad = peft_mod.init_adapters(jax.random.PRNGKey(1), params, cfg, pc)
    adapters = trees.select(with_ad, peft_mod.is_adapter_path)
    task_frac = trees.count_params(adapters) / total

    return {
        "arch": cfg.name,
        "total_params": total,
        "pretrain_frac": 1.0,
        "instruction_frac": instr_frac,
        "task_frac": task_frac,
        "rag_frac": 0.0,
    }


def main(archs=("gpt2-small", "roberta-base") + ASSIGNED[:4]):
    rows = [stage_fractions(a) for a in archs]
    print("arch,total_params,pretrain%,instruction%,task%,rag%")
    for r in rows:
        print(f"{r['arch']},{r['total_params']},100.0,"
              f"{100*r['instruction_frac']:.2f},{100*r['task_frac']:.2f},0.0")
    return rows


if __name__ == "__main__":
    main()
