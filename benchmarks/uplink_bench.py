"""Compressed factored uplink: bytes / delay / energy / accuracy-vs-bits.

For each PFTT method (pftt, fedlora, vanilla_fl) and each uplink codec
(none, int8, int4, sketch) this runs the fused cohort engine for a few
rounds over the simulated Rayleigh uplink and records the CommLedger
totals: encoded bytes per round, round delay, transmit energy, and the
accuracy curve — the paper's Fig. 5 communication panels with the
compression knob the PWFF claim rests on (quantized/sketched uploads,
arXiv:2407.02924-style bit-budget co-design).

Every codec run shares the no-codec run's seed, so channel gains, data
order and initialization match and the bytes/accuracy deltas isolate the
codec.  Acceptance targets (recorded in the JSON): int8 ≥4× and int4 ≥7×
uplink-bytes reduction vs the uncompressed factored upload at matched
accuracy (|Δacc| ≤ 1e-2 over the run).

A second block measures the SVD re-projection factored aggregation
(``repro.comms.factored_agg``): parity of the never-densified server path
against the dense-merge oracle on fedlora-shaped factors (≤1e-5), plus a
fedlora run with ``factored_agg=True`` stacked on int8.

    PYTHONPATH=src python -m benchmarks.run --only uplink      # quick
    FULL=1 PYTHONPATH=src python -m benchmarks.uplink_bench    # 6 rounds
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

METHODS = ("pftt", "fedlora", "vanilla_fl")
CODECS = ("none", "int8", "int4", "sketch")


def _run(method: str, codec: str, rounds: int, factored_agg: bool = False):
    from repro.core.pftt import PFTTConfig, run_pftt
    cfg = PFTTConfig(method=method, n_clients=4, rounds=rounds,
                     local_steps=5, d_model=64, pretrain_steps=60,
                     samples_per_client=400, seed=0, uplink_codec=codec,
                     factored_agg=factored_agg)
    r = run_pftt(cfg)
    return {"codec": codec, "factored_agg": factored_agg,
            "final_acc": r["final_acc"],
            "acc_per_round": r["acc_per_round"],
            "total_bytes": float(r["total_bytes"]),
            "mean_round_bytes": float(r["mean_round_bytes"]),
            "mean_round_delay_s": r["mean_round_delay_s"],
            "total_energy_j": r["total_energy_j"]}


def _svd_parity_block():
    """Never-densified SVD re-projection vs the dense-merge oracle on
    fedlora-shaped factors (the tests' ≤1e-5 criterion, recorded here so
    the trajectory is archived per commit)."""
    from repro.comms import dense_rank_r_oracle, svd_reproject
    rng = np.random.RandomState(0)
    n, rep, d, r = 4, 2, 128, 8
    st_a = jnp.asarray(rng.randn(n, rep, d, r) * d ** -0.5, jnp.float32)
    st_b = jnp.asarray(rng.randn(n, rep, r, d) * 0.02, jnp.float32)
    w = jnp.asarray([1.0, 0.0, 1.0, 0.5])
    a2, b2 = svd_reproject(st_a, st_b, w)
    oracle = dense_rank_r_oracle(st_a, st_b, w)
    err = float(jnp.abs(a2 @ b2 - oracle).max())
    return {"shape": f"n={n} rep={rep} d={d} r={r}",
            "max_abs_err_vs_dense_oracle": err,
            "passes_1e-5": bool(err <= 1e-5),
            "server_path_densifies": False}


def main(quick: bool = True, out: str = "BENCH_uplink.json"):
    rounds = 3 if quick else 6
    results = {}
    for method in METHODS:
        rows = []
        base = _run(method, "none", rounds)
        rows.append(base)
        for codec in CODECS[1:]:
            row = _run(method, codec, rounds)
            row["reduction_vs_none"] = base["total_bytes"] / \
                max(row["total_bytes"], 1e-9)
            row["delay_reduction_vs_none"] = (
                base["mean_round_delay_s"] /
                max(row["mean_round_delay_s"], 1e-12))
            row["acc_delta_vs_none"] = row["final_acc"] - base["final_acc"]
            rows.append(row)
            print(f"uplink_{method}_{codec},"
                  f"{row['mean_round_bytes']:.0f},"
                  f"x{row['reduction_vs_none']:.2f} "
                  f"delay x{row['delay_reduction_vs_none']:.2f} "
                  f"dacc={row['acc_delta_vs_none']:+.4f}")
        results[method] = rows

    # factored aggregation: SVD parity + the full stack on fedlora
    fa = _run("fedlora", "int8", rounds, factored_agg=True)
    fa_base = results["fedlora"][0]
    fa["acc_delta_vs_none"] = fa["final_acc"] - fa_base["final_acc"]
    fa["reduction_vs_none"] = fa_base["total_bytes"] / \
        max(fa["total_bytes"], 1e-9)
    print(f"uplink_fedlora_int8+svdagg,{fa['mean_round_bytes']:.0f},"
          f"x{fa['reduction_vs_none']:.2f} dacc={fa['acc_delta_vs_none']:+.4f}")
    svd = _svd_parity_block()
    print(f"# svd reprojection vs dense oracle: "
          f"max|err|={svd['max_abs_err_vs_dense_oracle']:.2e} "
          f"(<=1e-5: {svd['passes_1e-5']})")

    def _red(method, codec):
        return next(r["reduction_vs_none"] for r in results[method]
                    if r["codec"] == codec)

    def _dacc(method, codec):
        return next(abs(r["acc_delta_vs_none"]) for r in results[method]
                    if r["codec"] == codec)

    accept = {
        "int8_reduction_pftt": _red("pftt", "int8"),
        "int4_reduction_pftt": _red("pftt", "int4"),
        "int8_ge_4x": bool(all(_red(m, "int8") >= 4.0 for m in METHODS)),
        "int4_ge_7x": bool(all(_red(m, "int4") >= 7.0 for m in METHODS)),
        "pftt_acc_matched_1e-2": bool(_dacc("pftt", "int8") <= 1e-2
                                      and _dacc("pftt", "int4") <= 1e-2),
        "svd_parity_1e-5": svd["passes_1e-5"],
    }
    for k, v in accept.items():
        print(f"# accept[{k}] = {v}")

    record = {"profile": "quick" if quick else "full",
              "workload": "PFTT fused cohort engine, 4 clients, reduced "
                          f"roberta d64, {rounds} rounds, 5 local steps, "
                          "Rayleigh uplink snr=5dB; codec runs share the "
                          "no-codec run's seed/gains",
              "results": results,
              "factored_agg_fedlora_int8": fa,
              "svd_reprojection_parity": svd,
              "acceptance": accept}
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main(quick=not bool(os.environ.get("FULL")))
