"""Paper Fig. 4: PFIT vs SFL / PFL / Shepherd — reward curve (left) and
per-round communication cost (right)."""
from __future__ import annotations

import json
import os

from repro.core.pfit import PFITConfig, run_pfit


def main(rounds: int = 20, quick: bool = False, out: str = None):
    if quick:
        rounds = 4
    results = {}
    for method in ("pfit", "sfl", "pfl", "shepherd"):
        cfg = PFITConfig(method=method, rounds=rounds,
                         pretrain_steps=120 if quick else 250,
                         rm_steps=120 if quick else 250)
        results[method] = run_pfit(cfg)
        r = results[method]
        print(f"fig4 {method:10s} reward={r['final_reward']:.4f} "
              f"bytes/round={r['mean_round_bytes']:,.0f}")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(results, f, indent=1, default=float)
    return results


if __name__ == "__main__":
    main()
