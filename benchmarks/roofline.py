"""Roofline summary: reads the dry-run artifacts (experiments/dryrun/*.json)
and prints the per-(arch × shape × mesh) three-term roofline table used in
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_ms(s):
    return f"{1e3 * s:9.2f}"


def main(out_dir: str = "experiments/dryrun", mesh: str = None):
    rows = load(out_dir)
    if not rows:
        print(f"no dry-run artifacts in {out_dir} — run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    rows = [r for r in rows if mesh is None or r["mesh"] == mesh]
    print("arch,shape,mesh,step,compute_ms,memory_ms,collective_ms,"
          "dominant,useful_flops_ratio")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        ro = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['step']},"
              f"{1e3*ro['compute_s']:.3f},{1e3*ro['memory_s']:.3f},"
              f"{1e3*ro['collective_s']:.3f},{ro['dominant']},"
              f"{ratio if ratio is None else round(ratio, 3)}")
    return rows


if __name__ == "__main__":
    main()
