"""Population-scale FL: host orchestration cost + participation-skew cost.

Four measurements around ``fl/population.py``:

* **host overhead** — the acceptance pin: a 10k-client population with a
  64-client sampled cohort runs fused PFTT rounds; the host work
  population mode adds (sample + gather/overlay + scatter/global, timed
  inside ``PopulationRunner``) must stay <20% of round wall-clock.  The
  compiled round body is the same program a ``n_clients=64`` run
  compiles, so everything population-specific is in that fraction.
* **sampled-vs-standalone parity** — gather K rows from the store, run
  the fused robust round, scatter back: the rows must match the same
  clients run as a standalone K-client stack ≤1e-6 (same program, same
  inputs — bitwise in practice).
* **kill/resume** — a run killed after R/2 rounds and resumed from the
  checkpoint (store npz + sampler-RNG/tracker sidecar) must reproduce
  the uninterrupted run's accuracy and byte stream exactly.
* **participation skew** — a diurnal availability-weighted 8-of-32
  cohort vs the full-participation oracle (everyone trains every round)
  on the same non-IID population: the accuracy gap is the cost of
  sampling 25% participation, the regime the paper's cell serves.

    PYTHONPATH=src python -m benchmarks.run --only population   # quick
    FULL=1 PYTHONPATH=src python -m benchmarks.population_bench
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

POP_N, COHORT_K = 10_000, 64


def _pftt_kw(**over):
    kw = dict(local_steps=3, batch=4, pretrain_steps=10,
              samples_per_client=32, test_samples=8, d_model=32,
              lora_rank=2, adapter_dim=4, seed=0, verbose=False)
    kw.update(over)
    return kw


def _host_overhead(quick: bool) -> dict:
    from repro.core.pftt import PFTTConfig, run_pftt
    from repro.fl.population import PopulationConfig
    from repro.wireless.scenarios import Scenario

    rounds = 3 if quick else 8
    pop = PopulationConfig(
        population=POP_N, cohort_size=COHORT_K, sampler="availability",
        scenario=Scenario(alpha=0.1, avail="diurnal", avail_period=24,
                          mobility="waypoint", seed=1))
    t0 = time.perf_counter()
    res = run_pftt(PFTTConfig(population=pop, rounds=rounds,
                              **_pftt_kw()))
    wall = time.perf_counter() - t0
    row = {
        "population": POP_N, "cohort": COHORT_K, "rounds": rounds,
        "host_overhead_frac": res["host_overhead_frac"],
        "host_ms_per_round": 1e3 * res["host_s"] / rounds,
        "round_ms": 1e3 * res["round_s"] / rounds,
        "store_mb": res["store_bytes"] / 1e6,
        "participation_frac": res["participation_frac"],
        "final_acc": res["final_acc"],
        "total_wall_s": wall,
    }
    print(f"population_host,{row['host_overhead_frac']:.4f},"
          f"{POP_N} clients cohort {COHORT_K}: host "
          f"{row['host_ms_per_round']:.1f}ms of "
          f"{row['round_ms']:.1f}ms/round, store {row['store_mb']:.0f}MB")
    return row


def _parity() -> dict:
    """Store gather → fused robust round → scatter vs the same clients as
    a standalone cohort (the test asserts this too; the bench records the
    realized error)."""
    import jax
    import jax.numpy as jnp

    from repro import trees
    from repro.core.cohort import build_supervised_round
    from repro.fl.population import ClientSampler, PopulationStore
    from repro.optim import sgd

    N, K = 256, 8

    def loss_fn(tr, batch):
        return jnp.mean((tr["shared"]["w"].sum() + tr["local"]["v"].sum()
                         - batch["tgt"]) ** 2)

    opt = sgd(1e-2)

    def local_step(tr, op, batch):
        loss, grads = jax.value_and_grad(loss_fn)(tr, batch)
        upd, op = opt.update(grads, op, tr)
        return jax.tree_util.tree_map(lambda p, u: p + u, tr, upd), op, loss

    rng = np.random.RandomState(0)
    stacked = trees.stack(
        [{"shared": {"w": rng.randn(3).astype(np.float32)},
          "local": {"v": rng.randn(2).astype(np.float32)}}
         for _ in range(N)])
    opt0 = opt.init({"shared": {"w": jnp.zeros(3)},
                     "local": {"v": jnp.zeros(2)}})
    st_op = jax.tree_util.tree_map(
        lambda l: np.broadcast_to(np.asarray(l), (N,) + np.shape(l)).copy(),
        opt0)
    pend = jax.tree_util.tree_map(
        np.zeros_like, trees.select(stacked,
                                    lambda p: p.startswith("shared")))
    store = PopulationStore({"trainable": stacked, "opt": st_op,
                             "pending": pend})
    step = build_supervised_round(local_step,
                                  lambda p: p.startswith("shared"),
                                  donate=False, robust=True)
    ids = ClientSampler("uniform", N, K, seed=5).sample()
    batches = {"tgt": jnp.asarray(rng.randn(K, 2, 1), np.float32)}
    ones, zeros = jnp.ones(K), jnp.zeros(K)
    margs = (ones, ones, ones, zeros, ones)

    dev = lambda slot: jax.tree_util.tree_map(
        jnp.asarray, store.gather(slot, ids))
    ref = step(dev("trainable"), dev("opt"), dev("pending"), batches,
               *margs)
    out = step(dev("trainable"), dev("opt"), dev("pending"), batches,
               *margs)
    store.scatter("trainable", ids, out[0])
    store.scatter("pending", ids, out[2])

    err = 0.0
    for name, r in (("trainable", ref[0]), ("pending", ref[2])):
        back = store.gather(name, ids)
        for k, leaf in trees.flatten(r).items():
            err = max(err, float(np.max(np.abs(
                np.asarray(leaf) - trees.flatten(back)[k]))))
    row = {"population": N, "cohort": K, "max_abs_err": err,
           "passes_1e-6": bool(err <= 1e-6)}
    print(f"population_parity,{err:.2e},sampled round vs standalone cohort")
    return row


def _kill_resume(tmpdir: str) -> dict:
    from repro.core.pftt import PFTTConfig, run_pftt
    from repro.fl.population import PopulationConfig
    from repro.wireless.scenarios import Scenario

    def cfg(ckpt=None, resume=False, rounds=4):
        pop = PopulationConfig(
            population=64, cohort_size=8, sampler="availability",
            scenario=Scenario(alpha=0.1, avail="diurnal", avail_period=6,
                              mobility="waypoint", seed=1))
        return PFTTConfig(population=pop, rounds=rounds, ckpt_dir=ckpt,
                          resume=resume, **_pftt_kw(local_steps=2))

    full = run_pftt(cfg(rounds=4))
    run_pftt(cfg(ckpt=tmpdir, rounds=2))          # "killed" after 2 rounds
    res = run_pftt(cfg(ckpt=tmpdir, resume=True, rounds=4))
    exact = (full["acc_per_round"] == res["acc_per_round"]
             and full["total_bytes"] == res["total_bytes"])
    row = {"rounds": 4, "killed_after": 2, "exact": bool(exact),
           "acc_full": full["acc_per_round"],
           "acc_resumed": res["acc_per_round"],
           "bytes_full": float(full["total_bytes"]),
           "bytes_resumed": float(res["total_bytes"])}
    print(f"population_resume,{int(exact)},killed@2of4 "
          f"accs {['%.3f' % a for a in res['acc_per_round']]}")
    return row


def _participation_skew(quick: bool) -> dict:
    from repro.core.pftt import PFTTConfig, run_pftt
    from repro.fl.population import PopulationConfig
    from repro.wireless.scenarios import Scenario

    N, K = 32, 8
    rounds = 8 if quick else 16
    noniid = dict(alpha=0.1, avail_period=6, seed=1)
    sampled = run_pftt(PFTTConfig(
        population=PopulationConfig(
            population=N, cohort_size=K, sampler="availability",
            scenario=Scenario(avail="diurnal", **noniid)),
        rounds=rounds, **_pftt_kw()))
    # full-participation oracle: the whole population is the cohort each
    # round, same non-IID partition, no availability gating
    oracle = run_pftt(PFTTConfig(
        population=PopulationConfig(
            population=N, cohort_size=N, sampler="uniform",
            scenario=Scenario(**noniid)),
        rounds=rounds, **_pftt_kw()))
    row = {
        "population": N, "cohort": K, "rounds": rounds,
        "sampled_final_acc": sampled["final_acc"],
        "oracle_final_acc": oracle["final_acc"],
        "acc_delta": sampled["final_acc"] - oracle["final_acc"],
        "sampled_participation": sampled["participation_frac"],
        "bytes_ratio_oracle_over_sampled":
            float(oracle["total_bytes"])
            / max(float(sampled["total_bytes"]), 1.0),
    }
    print(f"population_skew,{row['acc_delta']:+.4f},"
          f"{K}/{N} diurnal sampled acc {row['sampled_final_acc']:.3f} vs "
          f"oracle {row['oracle_final_acc']:.3f} "
          f"({row['bytes_ratio_oracle_over_sampled']:.1f}x the uplink)")
    return row


def main(quick: bool = True, out: str = "BENCH_population.json"):
    import tempfile

    host = _host_overhead(quick)
    parity = _parity()
    with tempfile.TemporaryDirectory() as td:
        resume = _kill_resume(td)
    skew = _participation_skew(quick)

    accept = {
        "host_overhead_frac": host["host_overhead_frac"],
        "host_lt_20pct": bool(host["host_overhead_frac"] < 0.20),
        "parity_max_abs_err": parity["max_abs_err"],
        "parity_1e-6": parity["passes_1e-6"],
        "resume_exact": resume["exact"],
    }
    for k, v in accept.items():
        print(f"# accept[{k}] = {v}")

    record = {"profile": "quick" if quick else "full",
              "workload": f"PFTT population mode: {POP_N}-client host "
                          f"store (reduced roberta d32 rank-2 adapters), "
                          f"{COHORT_K}-client availability-weighted "
                          "cohorts through the fused robust round; "
                          "parity/resume/skew on small populations",
              "host_overhead": host,
              "parity": parity,
              "kill_resume": resume,
              "participation_skew": skew,
              "acceptance": accept}
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main(quick=not bool(os.environ.get("FULL")))
