"""Bench-smoke regression gate: fresh headline metrics vs the committed
reference BENCH files.

    PYTHONPATH=src python -m benchmarks.check_regression --ref <dir> \
        [--threshold 0.15]

``--ref`` points at a directory holding the COMMITTED ``BENCH_*.json``
files (CI copies them aside before ``benchmarks/run.py`` overwrites the
working tree).  For every benchmark in the fresh ``BENCH_manifest.json``
whose reference file exists, the same dotted headline path
(``benchmarks/manifest.py``) is extracted from both sides and the
degradation ratio computed in the metric's "good" direction — a
higher-is-better headline degrades when it shrinks, a lower-is-better one
when it grows.  Anything degraded more than ``--threshold`` (default 15%)
is listed in a delta table and the process exits 1 (the CI job stays
non-blocking; the table is the signal).  Missing references or freshly
added benchmarks are reported and skipped — a new benchmark can't fail
the gate before its reference lands.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.manifest import HEADLINES, MANIFEST_FILE, extract


def compare(fresh_dir: str, ref_dir: str, threshold: float):
    """→ (rows, regressions): one row per manifest entry; a row regresses
    when the headline degrades >threshold in its good direction."""
    mf = os.path.join(fresh_dir, MANIFEST_FILE)
    if not os.path.exists(mf):
        raise SystemExit(f"no {MANIFEST_FILE} in {fresh_dir!r} — run "
                         "benchmarks/run.py first")
    with open(mf) as f:
        manifest = json.load(f)

    rows, regressions = [], []
    for name, entry in sorted(manifest.items()):
        path = entry["metric"]
        higher = entry["higher_is_better"]
        fresh = float(entry["value"])
        ref_file = os.path.join(ref_dir, entry["file"])
        if not os.path.exists(ref_file):
            rows.append((name, path, None, fresh, None, "no reference"))
            continue
        with open(ref_file) as f:
            try:
                ref = float(extract(json.load(f), path))
            except (KeyError, IndexError, TypeError, ValueError):
                rows.append((name, path, None, fresh, None,
                             "reference lacks metric"))
                continue
        # degradation in the metric's good direction; guard zero refs
        if ref == 0.0:
            degr = 0.0 if fresh == ref else (1.0 if not higher else -1.0)
        else:
            degr = (ref - fresh) / ref if higher else (fresh - ref) / ref
        status = "REGRESSED" if degr > threshold else "ok"
        rows.append((name, path, ref, fresh, degr, status))
        if degr > threshold:
            regressions.append(name)
    return rows, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=".",
                    help="directory with the fresh run's manifest + BENCH "
                         "files (default: cwd)")
    ap.add_argument("--ref", required=True,
                    help="directory with the committed reference "
                         "BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated degradation of a headline ratio")
    args = ap.parse_args(argv)

    rows, regressions = compare(args.fresh, args.ref, args.threshold)

    print(f"{'benchmark':<14s} {'headline':<36s} {'ref':>10s} "
          f"{'fresh':>10s} {'delta':>8s}  status")
    for name, path, ref, fresh, degr, status in rows:
        ref_s = f"{ref:.4f}" if ref is not None else "-"
        degr_s = f"{-degr:+.1%}" if degr is not None else "-"
        print(f"{name:<14s} {path:<36s} {ref_s:>10s} {fresh:>10.4f} "
              f"{degr_s:>8s}  {status}")
    known_unrun = sorted(set(HEADLINES) - {r[0] for r in rows})
    if known_unrun:
        print(f"# not in this run's manifest (skipped): "
              f"{', '.join(known_unrun)}")

    if regressions:
        print(f"\n# REGRESSION: {len(regressions)} headline(s) degraded "
              f">{args.threshold:.0%}: {', '.join(regressions)}",
              file=sys.stderr)
        return 1
    print(f"\n# all headlines within {args.threshold:.0%} of the committed "
          "reference")
    return 0


if __name__ == "__main__":
    sys.exit(main())
