"""Straggler tolerance: round throughput + accuracy, bounded-staleness
engine vs the synchronous engine, at 10/30/50% injected straggler rates.

The synchronous engine gates every round on the slowest client: a client
straggling by ``k`` round-times makes the WHOLE cohort's round take
``1+k`` round-times (everyone idles while it finishes).  The
bounded-staleness engine never waits — stragglers' updates arrive ``k``
rounds late and merge with the ``α·(1+k)^(-a)`` discount — so each round
costs one round-time regardless of the fault draw.

Both engines run the SAME seeded ``FaultPlan`` trace and the comparison is
at equal simulated wall-clock: the robust engine's ``R`` rounds define the
time budget ``R`` (round-times); the synchronous engine completes however
many rounds fit when each one is stretched by that round's worst straggle
lag (derived from the trace — a straggle start at round ``r`` delivering at
``r+k`` blocks a synchronous server for ``k`` extra round-times).  Recorded
per rate: rounds completed, simulated time, throughput (rounds per
round-time), final accuracy, and the acceptance pair the issue pins —
at the 30% rate the robust engine sustains ≥2× the synchronous round
throughput with |Δacc| ≤ 0.02.

    PYTHONPATH=src python -m benchmarks.run --only straggler     # quick
    FULL=1 PYTHONPATH=src python -m benchmarks.straggler_bench
"""
from __future__ import annotations

import json
import os

import numpy as np

RATES = (0.1, 0.3, 0.5)
MAX_STRAGGLE = 3
STALENESS_A = 0.5


def _sync_round_times(trace) -> np.ndarray:
    """Per-round cost (in round-times) of a synchronous server replaying
    the trace: 1 + the worst straggle lag starting that round."""
    rounds, n = trace.train.shape
    lag = np.zeros(rounds)
    for c in range(n):
        r = 0
        while r < rounds:
            if trace.train[r, c] > 0 and trace.tx[r, c] == 0:
                r2 = r + 1                   # straggle start: find delivery
                while r2 < rounds and trace.tx[r2, c] == 0:
                    r2 += 1
                lag[r] = max(lag[r], r2 - r)
                r = r2 + 1
            else:
                r += 1
    return 1.0 + lag


def _bench_rate(rate: float, rounds: int, base_kw: dict) -> dict:
    from repro.core.pftt import PFTTConfig, run_pftt
    from repro.wireless.faults import FaultPlan

    plan = FaultPlan(straggle_p=rate, max_straggle=MAX_STRAGGLE, seed=11)
    trace = plan.realize(base_kw["n_clients"], rounds)
    sync_times = _sync_round_times(trace)

    # equal wall-clock: the robust engine's R rounds set the budget; the
    # synchronous engine fits fewer once rounds stretch to 1+k
    budget = float(rounds)
    cum = np.cumsum(sync_times)
    sync_rounds = max(1, int(np.searchsorted(cum, budget, side="right")))
    sync_time = float(cum[sync_rounds - 1])

    robust = run_pftt(PFTTConfig(
        engine=True, rounds=rounds, fault_plan=plan,
        staleness_a=STALENESS_A, max_staleness=MAX_STRAGGLE, **base_kw))
    # the synchronous server WAITS for stragglers (it never drops their
    # updates), so its training trajectory is the fault-free engine's —
    # it just completes fewer rounds in the budget
    sync = run_pftt(PFTTConfig(engine=True, rounds=sync_rounds, **base_kw))

    thr_robust = rounds / budget                     # 1.0 by construction
    thr_sync = sync_rounds / sync_time
    row = {
        "straggler_rate": rate,
        "robust": {"rounds": rounds, "sim_time": budget,
                   "throughput": thr_robust,
                   "final_acc": robust["final_acc"],
                   "total_bytes": float(robust["total_bytes"])},
        "sync": {"rounds": sync_rounds, "sim_time": sync_time,
                 "throughput": thr_sync,
                 "final_acc": sync["final_acc"],
                 "total_bytes": float(sync["total_bytes"])},
        "throughput_ratio": thr_robust / thr_sync,
        "acc_delta": robust["final_acc"] - sync["final_acc"],
    }
    print(f"straggler_{int(rate * 100)}pct,"
          f"{row['throughput_ratio']:.2f},"
          f"sync {sync_rounds}r/{sync_time:.0f}t vs robust {rounds}r/"
          f"{budget:.0f}t dacc={row['acc_delta']:+.4f}")
    return row


def main(quick: bool = True, out: str = "BENCH_straggler.json"):
    # the budget must let the SYNCHRONOUS run reach the accuracy plateau
    # (~6 fault-free rounds on this workload) or the Δacc comparison just
    # measures round count, not the staleness discount
    rounds = 16 if quick else 24
    base_kw = dict(n_clients=4, local_steps=5, d_model=64,
                   pretrain_steps=60, samples_per_client=400, seed=0)
    rows = [_bench_rate(rate, rounds, base_kw) for rate in RATES]

    at30 = next(r for r in rows if r["straggler_rate"] == 0.3)
    accept = {
        "throughput_ratio_at_30pct": at30["throughput_ratio"],
        "abs_acc_delta_at_30pct": abs(at30["acc_delta"]),
        "ge_2x_at_30pct": bool(at30["throughput_ratio"] >= 2.0),
        "acc_within_0.02_at_30pct": bool(abs(at30["acc_delta"]) <= 0.02),
    }
    for k, v in accept.items():
        print(f"# accept[{k}] = {v}")

    record = {"profile": "quick" if quick else "full",
              "workload": "PFTT fused cohort engine, "
                          f"{base_kw['n_clients']} clients, reduced roberta "
                          f"d64, {rounds} robust rounds, straggle-only "
                          f"FaultPlan (max_straggle={MAX_STRAGGLE}, "
                          f"seed=11), staleness a={STALENESS_A}; equal "
                          "simulated wall-clock (1 round-time per robust "
                          "round, 1+k per synchronous round blocked by a "
                          "k-round straggler)",
              "results": rows,
              "acceptance": accept}
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main(quick=not bool(os.environ.get("FULL")))
