"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] \
        [--only fl_engine,lora_path,cohort_shard]

Default is the quick profile (CI-friendly); ``--full`` (or env FULL=1) runs
the paper's 40-round simulations.  ``--only`` takes a comma-separated
subset.  Prints ``name,us_per_call,derived`` CSV blocks plus the per-figure
summaries, then a per-benchmark wall-time table (also persisted as
``BENCH_run_times.json``), and writes
``BENCH_manifest.json`` (benchmark → output file → headline metric, from
``benchmarks/manifest.py``) for the CI regression check
(``benchmarks/check_regression.py``).  A benchmark that raises is reported
(traceback + summary line) and the process exits nonzero after the
remaining selections finish — no silent failures in CI.
"""
import argparse
import os
import sys
import time
import traceback


def _benches():
    """name → thunk, in run order (imports stay lazy)."""

    def table1():
        print("# === Table I: learning-stage parameter/communication fractions ===")
        from benchmarks import table1_stages
        table1_stages.main()

    def kernels():
        print("\n# === kernel microbench (interpret mode; CSV: name,us_per_call,derived) ===")
        from benchmarks import kernel_bench
        kernel_bench.main()

    def fl_engine(quick):
        print("\n# === FL cohort engine: looped vs fused vmapped rounds ===")
        from benchmarks import fl_engine_bench
        fl_engine_bench.main(quick=quick, out="BENCH_fl_engine.json")

    def lora_path(quick):
        print("\n# === LoRA execution path: merged vs factored under client-vmap ===")
        from benchmarks import lora_path_bench
        lora_path_bench.main(quick=quick, out="BENCH_lora_path.json")

    def cohort_shard(quick):
        print("\n# === sharded cohort engine: fused round on 1 vs 8 devices ===")
        from benchmarks import cohort_shard_bench
        cohort_shard_bench.main(quick=quick, out="BENCH_cohort_shard.json")

    def uplink(quick):
        print("\n# === compressed factored uplink: bytes/delay/acc per codec ===")
        from benchmarks import uplink_bench
        uplink_bench.main(quick=quick, out="BENCH_uplink.json")

    def straggler(quick):
        print("\n# === straggler tolerance: bounded-staleness vs synchronous engine ===")
        from benchmarks import straggler_bench
        straggler_bench.main(quick=quick, out="BENCH_straggler.json")

    def deadline(quick):
        print("\n# === channel-driven deadlines: p75 cutoff vs wait-for-all ===")
        from benchmarks import deadline_bench
        deadline_bench.main(quick=quick, out="BENCH_deadline.json")

    def population(quick):
        print("\n# === population-scale FL: 10k-client store, sampled cohorts ===")
        from benchmarks import population_bench
        population_bench.main(quick=quick, out="BENCH_population.json")

    def obs(quick):
        print("\n# === run telemetry: events/trace/health overhead on the fused round ===")
        from benchmarks import obs_overhead_bench
        obs_overhead_bench.main(quick=quick, out="BENCH_obs.json")

    def fig5(quick):
        print("\n# === Fig. 5: PFTT accuracy / communication ===")
        from benchmarks import fig5_pftt
        fig5_pftt.main(quick=quick, out="experiments/fig5_pftt.json")

    def fig4(quick):
        print("\n# === Fig. 4: PFIT reward / communication ===")
        from benchmarks import fig4_pfit
        fig4_pfit.main(quick=quick, out="experiments/fig4_pfit.json")

    def roofline():
        print("\n# === Roofline (from dry-run artifacts) ===")
        from benchmarks import roofline as roofline_mod
        roofline_mod.main()

    return {"table1": lambda quick: table1(),
            "kernels": lambda quick: kernels(),
            "fl_engine": fl_engine,
            "lora_path": lora_path,
            "cohort_shard": cohort_shard,
            "uplink": uplink,
            "straggler": straggler,
            "deadline": deadline,
            "population": population,
            "obs": obs,
            "fig5": fig5,
            "fig4": fig4,
            "roofline": lambda quick: roofline()}


def main() -> None:
    benches = _benches()
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    default=bool(os.environ.get("FULL")))
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(benches))
    args, _ = ap.parse_known_args()
    quick = not args.full

    if args.only is None:
        selected = list(benches)
    else:
        selected = [s for s in args.only.split(",") if s]
        unknown = [s for s in selected if s not in benches]
        if unknown:
            print(f"unknown benchmark(s) {unknown}; choose from "
                  f"{sorted(benches)}", file=sys.stderr)
            sys.exit(2)

    t0 = time.time()
    failures = []
    timings = []
    for name in selected:
        tb = time.time()
        try:
            benches[name](quick)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"# BENCHMARK FAILED: {name} (continuing)", file=sys.stderr)
        timings.append((name, time.time() - tb))

    total_s = time.time() - t0
    print(f"\n# per-benchmark wall time:")
    for name, dt in timings:
        print(f"#   {name:<14s} {dt:7.1f}s"
              + ("  [FAILED]" if name in failures else ""))
    print(f"# total {total_s:.0f}s (quick={quick})")

    # persist the wall-time table next to the BENCH_*.json artifacts so a
    # CI run's cost profile is diffable, not just scrollback
    import json
    with open("BENCH_run_times.json", "w") as f:
        json.dump({"profile": "quick" if quick else "full",
                   "total_s": total_s,
                   "benchmarks": [{"name": name, "wall_s": dt,
                                   "failed": name in failures}
                                  for name, dt in timings]}, f, indent=1)

    # benchmark → output file → headline metric, so the CI regression
    # check never hardcodes file names (benchmarks/check_regression.py)
    from benchmarks.manifest import MANIFEST_FILE, write_manifest
    entries = write_manifest()
    print(f"# wrote {MANIFEST_FILE} "
          f"({', '.join(entries) if entries else 'no headline files found'})")

    if failures:
        print(f"# FAILED benchmarks: {','.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
