"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default is the quick profile (CI-friendly); ``--full`` (or env FULL=1) runs
the paper's 40-round simulations.  Prints ``name,us_per_call,derived`` CSV
blocks plus the per-figure summaries.
"""
import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    default=bool(os.environ.get("FULL")))
    ap.add_argument("--only", default=None,
                    choices=[None, "table1", "fig4", "fig5", "kernels",
                             "roofline", "fl_engine", "lora_path"])
    args, _ = ap.parse_known_args()
    quick = not args.full

    t0 = time.time()
    if args.only in (None, "table1"):
        print("# === Table I: learning-stage parameter/communication fractions ===")
        from benchmarks import table1_stages
        table1_stages.main()

    if args.only in (None, "kernels"):
        print("\n# === kernel microbench (interpret mode; CSV: name,us_per_call,derived) ===")
        from benchmarks import kernel_bench
        kernel_bench.main()

    if args.only in (None, "fl_engine"):
        print("\n# === FL cohort engine: looped vs fused vmapped rounds ===")
        from benchmarks import fl_engine_bench
        fl_engine_bench.main(quick=quick, out="BENCH_fl_engine.json")

    if args.only in (None, "lora_path"):
        print("\n# === LoRA execution path: merged vs factored under client-vmap ===")
        from benchmarks import lora_path_bench
        lora_path_bench.main(quick=quick, out="BENCH_lora_path.json")

    if args.only in (None, "fig5"):
        print("\n# === Fig. 5: PFTT accuracy / communication ===")
        from benchmarks import fig5_pftt
        fig5_pftt.main(quick=quick, out="experiments/fig5_pftt.json")

    if args.only in (None, "fig4"):
        print("\n# === Fig. 4: PFIT reward / communication ===")
        from benchmarks import fig4_pfit
        fig4_pfit.main(quick=quick, out="experiments/fig4_pfit.json")

    if args.only in (None, "roofline"):
        print("\n# === Roofline (from dry-run artifacts) ===")
        from benchmarks import roofline
        roofline.main()

    print(f"\n# total {time.time()-t0:.0f}s (quick={quick})")


if __name__ == "__main__":
    main()
