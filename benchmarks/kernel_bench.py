"""Kernel microbench: wall time of each Pallas kernel (interpret mode on
this CPU container — structural check + oracle comparison; real timings
come from a TPU run) and its jnp lowering path.  Emits
``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import SparseAttnConfig


def _time(fn, *args, n=3):
    res = fn(*args)                     # single warmup/compile call
    if isinstance(res, tuple):
        res[0].block_until_ready()
    else:
        jax.block_until_ready(res)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def main():
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 512, 8, 64))
    k = jax.random.normal(ks[1], (2, 512, 4, 64))
    v = jax.random.normal(ks[2], (2, 512, 4, 64))

    from repro.models.attention import (block_sparse_attention as sparse_jnp,
                                        chunked_attention, dense_attention)
    rows.append(("attn_dense_jnp", _time(jax.jit(
        lambda a, b, c: dense_attention(a, b, c)), q, k, v), "B2 S512 H8 d64"))
    rows.append(("attn_chunked_jnp", _time(jax.jit(
        lambda a, b, c: chunked_attention(a, b, c, q_block=128,
                                          kv_block=128)), q, k, v),
        "flash-style scan"))
    scfg = SparseAttnConfig(block_size=64, local_blocks=2, sink_blocks=1,
                            stride=4)
    rows.append(("attn_block_sparse_jnp", _time(jax.jit(
        lambda a, b, c: sparse_jnp(a, b, c, scfg)), q, k, v),
        "paper technique, gather-based"))

    from repro.kernels.flash_attn.ops import flash_attention
    rows.append(("flash_attn_pallas_interpret", _time(
        lambda a, b, c: flash_attention(a, b, c, bq=128, bk=128), q, k, v),
        "interpret=True"))
    from repro.kernels.block_sparse_attn.ops import block_sparse_attention
    rows.append(("block_sparse_pallas_interpret", _time(
        lambda a, b, c: block_sparse_attention(a, b, c, scfg), q, k, v),
        "interpret=True"))

    from repro.kernels.ssd_chunk.ops import ssd_scan
    from repro.models.ssm import ssd_chunk_scan
    kss = jax.random.split(jax.random.PRNGKey(1), 5)
    B, S, H, P, N = 2, 512, 8, 64, 32
    x = jax.random.normal(kss[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(kss[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(kss[2], (H,)) * 0.3)
    bm = jax.random.normal(kss[3], (B, S, H, N)) * 0.5
    cm = jax.random.normal(kss[4], (B, S, H, N)) * 0.5
    rows.append(("ssd_chunk_jnp", _time(jax.jit(
        lambda *t: ssd_chunk_scan(*t, 128)), x, dt, a, bm, cm),
        "matmul-form chunked"))
    rows.append(("ssd_chunk_pallas_interpret", _time(
        lambda *t: ssd_scan(*t, chunk=128), x, dt, a, bm, cm),
        "interpret=True"))

    from repro.kernels.lora_fused.ops import lora_matmul
    from repro.kernels.lora_fused.ref import lora_ref
    from repro.models.peft import lora_proj
    kl = jax.random.split(jax.random.PRNGKey(2), 4)
    xm = jax.random.normal(kl[0], (512, 512))
    w = jax.random.normal(kl[1], (512, 512)) * 0.05
    am = jax.random.normal(kl[2], (512, 16)) * 0.05
    bm2 = jax.random.normal(kl[3], (16, 512)) * 0.05
    rows.append(("lora_merged_dense_jnp", _time(jax.jit(
        lambda x, wg, a, b: x @ (wg + 2.0 * a @ b)), xm, w, am, bm2),
        "materialize W+sAB then matmul"))
    rows.append(("lora_factored_jnp", _time(jax.jit(
        lambda x, wg, a, b: lora_proj(x, wg, {"a": a, "b": b,
                                              "mask": jnp.ones(())},
                                      scale=2.0)), xm, w, am, bm2),
        "x@W + s(x@A)@B via peft.lora_proj"))
    rows.append(("lora_two_matmul_jnp", _time(jax.jit(
        lambda *t: lora_ref(*t, scale=2.0)), xm, w, am, bm2), "unfused"))
    rows.append(("lora_fused_pallas_interpret", _time(
        lambda *t: lora_matmul(*t, scale=2.0), xm, w, am, bm2),
        "interpret=True"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
