"""Channel-driven deadlines: simulated round time vs accuracy.

The wait-for-all server closes each round at the LAST clean arrival, so a
single deep Rayleigh fade (rate → ~0) stretches the whole cohort's round.
The deadline server closes at a fixed cutoff; late payloads buffer as
pending retransmissions and merge in a later round under the
``α·(1+s)^(-a)`` staleness discount (``core/robust.py`` +
``wireless/arrivals.py``).

Protocol: run the continuous-time round with an INFINITE deadline first
(same channel/compute seeds), collect every clean arrival time from the
ledger, and set the deadline at the p75 of that empirical distribution.
Rerun with the p75 deadline.  Acceptance, as the issue pins: the deadline
run cuts total simulated time ≥ 1.5× while |Δ final accuracy| ≤ 0.02.

    PYTHONPATH=src python -m benchmarks.run --only deadline      # quick
    FULL=1 PYTHONPATH=src python -m benchmarks.deadline_bench
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

COMPUTE_S = 0.002     # mean local-compute time (uplink airtime dominates)
STALENESS_A = 0.5
MAX_STALENESS = 3
PCTL = 75


def main(quick: bool = True, out: str = "BENCH_deadline.json"):
    from repro.core.pftt import PFTTConfig, run_pftt
    from repro.wireless import DeadlineConfig

    rounds = 12 if quick else 24
    base_kw = dict(n_clients=8, rounds=rounds, local_steps=5, d_model=64,
                   pretrain_steps=60, samples_per_client=400, seed=0,
                   staleness_a=STALENESS_A, max_staleness=MAX_STALENESS)

    # --- pass 1: wait for everyone (inf deadline, same seeds) -------------
    wait_all = run_pftt(PFTTConfig(deadline=DeadlineConfig(
        deadline_s=math.inf, compute_mean_s=COMPUTE_S, seed=13), **base_kw))
    arrivals = [pc["delay_s"] for rec in wait_all["round_records"]
                for pc in rec["per_client"] if not pc["outage"]]
    cutoff = float(np.percentile(arrivals, PCTL))

    # --- pass 2: p75 deadline, everything else identical ------------------
    deadline = run_pftt(PFTTConfig(deadline=DeadlineConfig(
        deadline_s=cutoff, compute_mean_s=COMPUTE_S, seed=13), **base_kw))

    ratio = wait_all["total_sim_time_s"] / max(deadline["total_sim_time_s"],
                                               1e-12)
    dacc = deadline["final_acc"] - wait_all["final_acc"]
    attempts = sum(len(rec["per_client"])
                   for rec in deadline["round_records"])
    failed = sum(pc["outage"] for rec in deadline["round_records"]
                 for pc in rec["per_client"])    # deadline miss/outage/NACK
    print(f"deadline_p{PCTL},{ratio:.2f},"
          f"cutoff={cutoff * 1e3:.2f}ms wait_all="
          f"{wait_all['total_sim_time_s']:.3f}s deadline="
          f"{deadline['total_sim_time_s']:.3f}s dacc={dacc:+.4f} "
          f"failed_attempts={failed}/{attempts}")

    accept = {
        "sim_time_ratio": ratio,
        "abs_acc_delta": abs(dacc),
        "ge_1p5x_sim_time": bool(ratio >= 1.5),
        "acc_within_0.02": bool(abs(dacc) <= 0.02),
    }
    for k, v in accept.items():
        print(f"# accept[{k}] = {v}")

    def _row(res):
        return {"final_acc": res["final_acc"],
                "total_sim_time_s": res["total_sim_time_s"],
                "total_bytes": float(res["total_bytes"]),
                "total_energy_j": float(res["total_energy_j"]),
                "quorum_noops": res["quorum_noops"]}

    record = {"profile": "quick" if quick else "full",
              "workload": "PFTT fused cohort engine, "
                          f"{base_kw['n_clients']} clients, reduced roberta "
                          f"d64, {rounds} continuous-time rounds over the "
                          "Rayleigh uplink (no injected faults: staleness "
                          "is emergent from realized rates), staleness "
                          f"a={STALENESS_A}, max_staleness={MAX_STALENESS}, "
                          f"compute_mean_s={COMPUTE_S}",
              "deadline_s": cutoff,
              "percentile": PCTL,
              "n_arrivals": len(arrivals),
              "wait_all": _row(wait_all),
              "deadline": _row(deadline),
              "acceptance": accept}
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main(quick=not bool(os.environ.get("FULL")))
