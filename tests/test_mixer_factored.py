"""Factored LoRA through the non-dense mixer families: MLA and Mamba.

The universal fused path requires every mixer family to accept the
{'a','b','mask'} factor side channel unmerged: MLA's four low-rank
projections (``wq_a``/``wq_b``/``wkv_a``/``wkv_b``, including the
absorbed-decode latent-space merge), Mamba's ``in_proj``/``out_proj``, and
the Jamba attention+SSM hybrid.  Parity target is the ``apply_lora``
dense-merge oracle — forward hidden states, LM loss, factor gradients,
prefill/decode logits — under per-client vmap (frozen base unbatched) and
through ``run_arch_round`` on a 1-device mesh.  The trace-time
``peft.dense_merge_count`` counter proves the factored path never
materializes a dense delta."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import trees
from repro.configs import get_config
from repro.models import Model
from repro.models import peft as peft_mod
from repro.sharding import MeshCtx

KEY = jax.random.PRNGKey(0)

MLA_TARGETS = ("mixer/wq_a", "mixer/wq_b", "mixer/wkv_a", "mixer/wkv_b")
SSM_TARGETS = ("mixer/in_proj", "mixer/out_proj")


def _randomize_factors(lora, seed=1):
    """init_lora zeros B (delta starts at 0); give every factor leaf real
    values so parity actually exercises the low-rank path."""
    def rnd(x):
        if hasattr(x, "ndim") and x.ndim >= 2 and x.shape[-2:] != (1, 1):
            return jax.random.normal(jax.random.fold_in(KEY, seed),
                                     x.shape) * 0.05
        return x
    return jax.tree_util.tree_map(rnd, lora)


def _mk(arch, targets, d_model=32, repeats=2, rank=4, seed=1):
    mcfg = get_config(arch).reduced(d_model=d_model, repeats=repeats)
    model = Model(mcfg, meshctx=MeshCtx.single_device())
    params = model.init(KEY, max_seq=64)
    pc = peft_mod.PEFTConfig(lora_rank=rank, lora_alpha=2.0 * rank,
                             lora_targets=targets)
    lora = _randomize_factors(peft_mod.init_lora(KEY, params, pc), seed=seed)
    return mcfg, model, params, pc, lora


def _toks(mcfg, shape=(2, 12), seed=2):
    return jax.random.randint(jax.random.fold_in(KEY, seed), shape, 6,
                              mcfg.vocab_size)


def _lm_batch(mcfg, b=2, s=12, seed=2):
    toks = np.asarray(_toks(mcfg, (b, s + 1), seed))
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "mask": jnp.ones((b, s), jnp.float32)}


# ---------------------------------------------------------------------------
# forward / loss / gradient parity vs the dense-merge oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,targets", [
    ("deepseek-v2-236b", MLA_TARGETS),
    ("mamba2-1.3b", SSM_TARGETS),
    ("jamba-v0.1-52b", ("mixer/wq", "mixer/wv") + SSM_TARGETS),
])
def test_forward_parity(arch, targets):
    mcfg, model, params, pc, lora = _mk(arch, targets)
    toks = _toks(mcfg)
    merged = peft_mod.apply_lora(params, lora, pc)
    h_m, _ = model.forward(merged, toks)
    h_f, _ = model.forward(params, toks, lora=lora,
                           lora_scale=peft_mod.lora_scale(pc))
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_m), atol=1e-5)


@pytest.mark.parametrize("arch,targets", [
    ("deepseek-v2-236b", MLA_TARGETS),
    ("mamba2-1.3b", SSM_TARGETS),
])
def test_loss_and_grad_parity(arch, targets):
    mcfg, model, params, pc, lora = _mk(arch, targets)
    batch = _lm_batch(mcfg)
    scale = peft_mod.lora_scale(pc)
    lm, gm = jax.value_and_grad(lambda lo: model.lm_loss(
        peft_mod.apply_lora(params, lo, pc), batch))(lora)
    lf, gf = jax.value_and_grad(lambda lo: model.lm_loss(
        params, batch, lora=lo, lora_scale=scale))(lora)
    np.testing.assert_allclose(float(lf), float(lm), atol=1e-5)
    flat_f = trees.flatten(gf)
    for path, gmv in trees.flatten(gm).items():
        np.testing.assert_allclose(np.asarray(flat_f[path]), np.asarray(gmv),
                                   atol=1e-5, err_msg=path)


def test_factored_forward_traces_zero_dense_merges():
    """The observable no-fallback invariant: tracing the factored forward
    must not bump the dense-merge counter (the oracle path must)."""
    for arch, targets in (("deepseek-v2-236b", MLA_TARGETS),
                          ("mamba2-1.3b", SSM_TARGETS)):
        mcfg, model, params, pc, lora = _mk(arch, targets)
        toks = _toks(mcfg)
        m0 = peft_mod.dense_merge_count()
        model.forward(params, toks, lora=lora,
                      lora_scale=peft_mod.lora_scale(pc))
        assert peft_mod.dense_merge_count() == m0, arch
        peft_mod.apply_lora(params, lora, pc)
        assert peft_mod.dense_merge_count() > m0   # counter itself works


# ---------------------------------------------------------------------------
# serving parity: prefill + cached decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,targets", [
    ("deepseek-v2-236b", MLA_TARGETS),   # absorbed decode: latent-space merge
    ("mamba2-1.3b", SSM_TARGETS),        # conv/ssm state caches
    ("jamba-v0.1-52b", ("mixer/wq", "mixer/wv") + SSM_TARGETS),
])
def test_prefill_decode_parity(arch, targets):
    mcfg, model, params, pc, lora = _mk(arch, targets)
    scale = peft_mod.lora_scale(pc)
    prompts = _toks(mcfg, (2, 8), seed=3)
    merged = peft_mod.apply_lora(params, lora, pc)
    lg_m, c_m = model.prefill(merged, prompts, cache_len=12)
    lg_f, c_f = model.prefill(params, prompts, cache_len=12, lora=lora,
                              lora_scale=scale)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_m), atol=1e-4)
    tok = jnp.argmax(lg_m, -1)[:, None].astype(jnp.int32)
    d_m, _ = model.decode_step(merged, c_m, tok)
    d_f, _ = model.decode_step(params, c_f, tok, lora=lora, lora_scale=scale)
    np.testing.assert_allclose(np.asarray(d_f), np.asarray(d_m), atol=1e-4)


def test_launch_serve_steps_thread_lora():
    """launch.steps prefill/serve builders expose the factored side channel."""
    from repro.launch.steps import make_prefill_step, make_serve_step
    mcfg, model, params, pc, lora = _mk("deepseek-v2-236b", MLA_TARGETS)
    scale = peft_mod.lora_scale(pc)
    prompts = _toks(mcfg, (2, 8), seed=3)
    prefill = make_prefill_step(model, cache_len=12, lora_scale=scale)
    serve = make_serve_step(model, lora_scale=scale)
    lg_f, cache = prefill(params, {"tokens": prompts}, lora=lora)
    merged = peft_mod.apply_lora(params, lora, pc)
    lg_m, _ = model.prefill(merged, prompts, cache_len=12)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_m), atol=1e-4)
    tok = jnp.argmax(lg_m, -1)[:, None].astype(jnp.int32)
    d_f, _ = serve(params, cache, tok, lora=lora)
    assert d_f.shape == (2, mcfg.vocab_size)


# ---------------------------------------------------------------------------
# client vmap: frozen base stays unbatched, only factors carry the axis
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,targets", [
    ("deepseek-v2-236b", MLA_TARGETS),
    ("mamba2-1.3b", SSM_TARGETS),
])
def test_client_vmap_parity(arch, targets):
    mcfg, model, params, pc, _ = _mk(arch, targets)
    scale = peft_mod.lora_scale(pc)
    loras = [_randomize_factors(peft_mod.init_lora(KEY, params, pc), seed=s)
             for s in (1, 2, 3)]
    batches = [_lm_batch(mcfg, seed=10 + s) for s in range(3)]
    stacked_lora = trees.stack(loras)
    stacked_batch = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *batches)

    def client_loss(lf, b):       # params closed over: unbatched base
        return model.lm_loss(params, b, lora=lf, lora_scale=scale)

    fused = jax.vmap(client_loss)(stacked_lora, stacked_batch)
    for ci in range(3):
        ref = model.lm_loss(peft_mod.apply_lora(params, loras[ci], pc),
                            batches[ci])
        np.testing.assert_allclose(float(fused[ci]), float(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: fused federated round on a 1-device mesh vs oracle loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "mamba2-1.3b"])
def test_arch_round_one_device_mesh_matches_oracle(arch):
    from repro.core.arch_round import ArchRoundConfig, run_arch_round
    mesh = jax.make_mesh((1,), ("data",))
    res = run_arch_round(
        ArchRoundConfig(arch=arch, n_clients=2, rounds=1, local_steps=2,
                        batch=3, seq_len=12, d_model=32, oracle=True),
        mesh=mesh, client_axes=("data",))
    assert res["dense_merges_in_engine"] == 0
    assert res["dispatches_per_round"] == 1.0
    assert res["ragged"]                       # unequal client batch sizes
    assert res["oracle_loss_max_err"] <= 1e-5


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "dbrx-132b",
                                  "whisper-base", "gpt2-small"])
def test_arch_round_matrix_remaining_cells(arch):
    from repro.core.arch_round import ArchRoundConfig, run_arch_round
    res = run_arch_round(
        ArchRoundConfig(arch=arch, n_clients=2, rounds=1, local_steps=2,
                        batch=3, seq_len=12, d_model=32, oracle=True))
    assert res["dense_merges_in_engine"] == 0
    assert res["dispatches_per_round"] == 1.0
    assert res["oracle_loss_max_err"] <= 1e-5
