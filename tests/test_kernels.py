"""Pallas kernel sweeps (deliverable c): shapes × dtypes, assert_allclose
against the pure-jnp oracles, interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparseAttnConfig


def _qkv(key, b, sq, sk, h, kh, d, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, kh, d), dtype)
    v = jax.random.normal(k3, (b, sk, kh, d), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kh,d,bq,bk", [
    (2, 256, 8, 4, 64, 64, 64),
    (1, 128, 4, 4, 32, 128, 32),
    (2, 512, 4, 1, 64, 128, 128),
])
@pytest.mark.parametrize("window", [0, 96])
def test_flash_attention_sweep(dtype, b, s, h, kh, d, bq, bk, window):
    from repro.kernels.flash_attn.ops import flash_attention
    from repro.models.attention import dense_attention
    q, k, v = _qkv(jax.random.PRNGKey(0), b, s, s, h, kh, d, dtype)
    out = flash_attention(q, k, v, causal=True, window=window, bq=bq, bk=bk)
    ref = dense_attention(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scfg", [
    SparseAttnConfig(block_size=32, local_blocks=2, sink_blocks=1, stride=4),
    SparseAttnConfig(block_size=64, local_blocks=1, sink_blocks=2, stride=2),
])
def test_block_sparse_sweep(dtype, scfg):
    from repro.kernels.block_sparse_attn.ops import block_sparse_attention
    from repro.models.attention import block_sparse_attention as jnp_sparse
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 256, 256, 8, 4, 64, dtype)
    out = block_sparse_attention(q, k, v, scfg)
    ref = jnp_sparse(q, k, v, scfg)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_block_sparse_kernel_vs_dense_masked_oracle():
    from repro.kernels.block_sparse_attn.kernel import block_sparse_attention_kernel
    from repro.kernels.block_sparse_attn.ref import block_sparse_ref
    from repro.models.attention import sparse_block_table
    scfg = SparseAttnConfig(block_size=32, local_blocks=2, sink_blocks=1,
                            stride=4)
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (4, 256, 32))
    k = jax.random.normal(k2, (2, 256, 32))
    v = jax.random.normal(k3, (2, 256, 32))
    idx, valid = sparse_block_table(8, 8, scfg)
    out = block_sparse_attention_kernel(q, k, v, jnp.asarray(idx),
                                        jnp.asarray(valid.astype(np.int32)),
                                        block=32)
    ref = block_sparse_ref(q, k, v, idx, valid, block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("p,n", [(16, 8), (32, 16)])
def test_ssd_chunk_sweep(dtype, chunk, p, n):
    from repro.kernels.ssd_chunk.ops import ssd_scan
    from repro.kernels.ssd_chunk.ref import ssd_ref
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    b, s, h = 2, 128, 2
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, h, n)) * 0.5
    y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    y_r, h_r = ssd_ref(xf, dtf, jnp.tile(a, b),
                       bm.transpose(0, 2, 1, 3).reshape(b * h, s, n),
                       cm.transpose(0, 2, 1, 3).reshape(b * h, s, n))
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_r.reshape(b, h, s, p).transpose(0, 2, 1, 3)),
        atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf),
                               np.asarray(h_r.reshape(b, h, p, n)),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,r", [(128, 256, 384, 8), (64, 128, 128, 16)])
def test_lora_fused_sweep(dtype, m, k, n, r):
    from repro.kernels.lora_fused.ops import lora_matmul
    from repro.kernels.lora_fused.ref import lora_ref
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w = (jax.random.normal(ks[1], (k, n)) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (k, r)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (r, n)) * 0.05).astype(dtype)
    out = lora_matmul(x, w, a, b, scale=2.0)
    ref = lora_ref(x, w, a, b, scale=2.0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_lora_fused_matches_merged_weights():
    """Fused kernel == apply_lora-merged dense matmul (serving equivalence)."""
    from repro.kernels.lora_fused.ops import lora_matmul
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(ks[0], (32, 128))
    w = jax.random.normal(ks[1], (128, 128)) * 0.05
    a = jax.random.normal(ks[2], (128, 8)) * 0.05
    b = jax.random.normal(ks[3], (8, 128)) * 0.05
    merged = w + 2.0 * (a @ b)
    np.testing.assert_allclose(np.asarray(lora_matmul(x, w, a, b, scale=2.0)),
                               np.asarray(x @ merged), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("pos,window", [(100, 0), (255, 0), (200, 64), (0, 0)])
def test_decode_attention_kernel_sweep(dtype, pos, window):
    from repro.kernels.decode_attn.ops import decode_attention
    from repro.models.attention import decode_attention as jnp_decode
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 1, 8, 64), dtype)
    k = jax.random.normal(ks[1], (2, 256, 4, 64), dtype)
    v = jax.random.normal(ks[2], (2, 256, 4, 64), dtype)
    out = decode_attention(q, k, v, pos, window=window, bk=64)
    ref = jnp_decode(q, k, v, cache_len=pos + 1, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)
