"""Population-scale FL: store gather/scatter, seeded samplers, scenario
traces, and the sampled-cohort round's parity with a standalone cohort.

The contract under test (fl/population.py + wireless/scenarios.py):

* ``ClientSampler`` is one seeded stream — same seed → same cohort
  sequence, and a ``state_dict`` snapshot restored mid-stream reproduces
  the uninterrupted sequence exactly (checkpoint resume).
* ``PopulationStore.gather``/``scatter`` round-trip rows losslessly,
  never touch unsampled rows, and reuse ONE staging buffer per slot
  (steady-state rounds allocate nothing).
* A sampled cohort pushed through the fused robust round body and
  scattered back equals the same clients run as a standalone
  ``n_clients=cohort`` stack, ≤1e-6 (here: bitwise — same program, same
  inputs).
* ``Scenario.realize`` is a pure function of the spec: per-axis draw
  blocks keep class_probs stable when availability/mobility toggle.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import trees
from repro.fl.population import (ClientSampler, PopulationConfig,
                                 PopulationData, PopulationStore,
                                 stacked_client_init)
from repro.wireless.scenarios import Scenario

# ---------------------------------------------------------------------------
# ClientSampler: determinism + mid-stream resume
# ---------------------------------------------------------------------------


def test_sampler_same_seed_same_stream():
    a = ClientSampler("uniform", 100, 8, seed=7)
    b = ClientSampler("uniform", 100, 8, seed=7)
    for _ in range(10):
        np.testing.assert_array_equal(a.sample(), b.sample())


def test_sampler_different_seed_differs():
    a = ClientSampler("uniform", 1000, 8, seed=0)
    b = ClientSampler("uniform", 1000, 8, seed=1)
    assert any(not np.array_equal(a.sample(), b.sample()) for _ in range(5))


def test_sampler_cohort_shape_and_uniqueness():
    s = ClientSampler("uniform", 50, 16, seed=0)
    for _ in range(20):
        ids = s.sample()
        assert ids.shape == (16,)
        assert len(np.unique(ids)) == 16
        assert np.all(np.diff(ids) > 0)          # sorted, no repeats
        assert ids.min() >= 0 and ids.max() < 50


def test_sampler_midstream_resume_reproduces_stream():
    """A state_dict taken mid-stream resumes into the SAME uninterrupted
    cohort sequence (the checkpoint/resume contract)."""
    ref = ClientSampler("uniform", 200, 8, seed=3)
    full = [ref.sample() for _ in range(12)]

    first = ClientSampler("uniform", 200, 8, seed=3)
    for _ in range(5):
        first.sample()
    snap = first.state_dict()

    resumed = ClientSampler("uniform", 200, 8, seed=3)
    resumed.load_state_dict(snap)
    for r in range(5, 12):
        np.testing.assert_array_equal(resumed.sample(), full[r])


def test_sampler_state_dict_json_roundtrip():
    import json
    s = ClientSampler("availability", 64, 4, seed=1)
    p = np.linspace(0.1, 1.0, 64)
    s.sample(p)
    snap = json.loads(json.dumps(s.state_dict()))   # sidecar is JSON
    t = ClientSampler("availability", 64, 4, seed=99)
    t.load_state_dict(snap)
    for _ in range(5):
        np.testing.assert_array_equal(s.sample(p), t.sample(p))


def test_availability_sampler_skews_to_reachable():
    s = ClientSampler("availability", 100, 10, seed=0)
    p = np.full(100, 1e-6)
    p[:20] = 1.0            # only the first 20 clients are reachable
    counts = np.zeros(100)
    for _ in range(50):
        counts[s.sample(p)] += 1
    assert counts[:20].sum() > 0.99 * counts.sum()


def test_sampler_unknown_kind_raises():
    with pytest.raises(ValueError):
        ClientSampler("roundrobin", 10, 2)


# ---------------------------------------------------------------------------
# PopulationConfig validation
# ---------------------------------------------------------------------------


def test_population_config_validates():
    PopulationConfig(population=100, cohort_size=8)
    with pytest.raises(ValueError):
        PopulationConfig(population=4, cohort_size=8)
    with pytest.raises(ValueError):
        PopulationConfig(population=10, cohort_size=0)
    with pytest.raises(ValueError):
        PopulationConfig(population=10, cohort_size=2, sampler="magic")
    # availability sampling needs an availability trace to weight by
    with pytest.raises(ValueError):
        PopulationConfig(population=10, cohort_size=2,
                         sampler="availability")
    with pytest.raises(ValueError):
        PopulationConfig(population=10, cohort_size=2,
                         sampler="availability", scenario=Scenario())
    PopulationConfig(population=10, cohort_size=2, sampler="availability",
                     scenario=Scenario(avail="diurnal"))


# ---------------------------------------------------------------------------
# PopulationStore: gather/scatter round-trip, isolation, buffer reuse
# ---------------------------------------------------------------------------


def _toy_store(n, seed=0):
    r = np.random.RandomState(seed)
    tree = {"a": {"w": r.randn(n, 3, 4).astype(np.float32)},
            "b": r.randn(n, 5).astype(np.float32)}
    return PopulationStore({"trainable": tree}), tree


def test_store_gather_scatter_roundtrip():
    store, ref = _toy_store(32)
    ids = np.asarray([3, 7, 11, 30])
    g = store.gather("trainable", ids)
    np.testing.assert_array_equal(g["a"]["w"], ref["a"]["w"][ids])
    np.testing.assert_array_equal(g["b"], ref["b"][ids])
    store.scatter("trainable", ids, jax.tree_util.tree_map(jnp.asarray, g))
    np.testing.assert_array_equal(store.slots["trainable"]["a"]["w"],
                                  ref["a"]["w"])


def test_store_scatter_leaves_unsampled_rows_untouched():
    store, ref = _toy_store(16)
    ids = np.asarray([2, 5])
    new = jax.tree_util.tree_map(
        lambda l: jnp.zeros((2,) + l.shape[1:], l.dtype),
        store.gather("trainable", ids))
    store.scatter("trainable", ids, new)
    mask = np.ones(16, bool)
    mask[ids] = False
    np.testing.assert_array_equal(store.slots["trainable"]["b"][mask],
                                  ref["b"][mask])
    np.testing.assert_array_equal(store.slots["trainable"]["b"][ids], 0.0)


def test_store_gather_ghost_pad_repeats_first_row():
    store, ref = _toy_store(8)
    ids = np.asarray([1, 4])
    g = store.gather("trainable", ids, pad_to=5)
    assert g["b"].shape == (5, 5)
    for ghost in range(2, 5):
        np.testing.assert_array_equal(g["b"][ghost], ref["b"][1])


def test_store_gather_reuses_staging_buffer():
    """Steady-state rounds must not allocate: the second gather refills the
    SAME numpy buffer objects."""
    store, _ = _toy_store(16)
    g1 = store.gather("trainable", np.asarray([0, 1]), pad_to=4)
    g2 = store.gather("trainable", np.asarray([9, 3]), pad_to=4)
    assert g1["b"] is g2["b"]
    assert g1["a"]["w"] is g2["a"]["w"]


def test_store_scatter_copies_out_of_device_buffer():
    """scatter must COPY device results: a zero-copy view of a donated jax
    buffer would dangle once the next round rebinds it."""
    store, _ = _toy_store(4)
    ids = np.asarray([0, 1])
    dev = jax.tree_util.tree_map(jnp.asarray, store.gather("trainable", ids))
    store.scatter("trainable", ids, dev)
    for leaf in jax.tree_util.tree_leaves(store.slots["trainable"]):
        assert leaf.flags.writeable            # host-owned, not a jax view


def test_store_zero_rows():
    store, ref = _toy_store(8)
    store.zero_rows("trainable", [2, 6])
    np.testing.assert_array_equal(store.slots["trainable"]["b"][2], 0.0)
    np.testing.assert_array_equal(store.slots["trainable"]["b"][5],
                                  ref["b"][5])


def test_store_checkpoint_roundtrip():
    store, ref = _toy_store(8)
    tree = store.checkpoint_tree()
    store2, _ = _toy_store(8, seed=1)
    store2.load_checkpoint_tree(tree)
    np.testing.assert_array_equal(store2.slots["trainable"]["b"], ref["b"])
    # restored slots stay writable (np.savez round-trips can return
    # read-only arrays)
    store2.zero_rows("trainable", [0])


def test_stacked_client_init_broadcasts_constants():
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(0), i))(
        jnp.arange(6))
    st = stacked_client_init(
        lambda k: {"w": jax.random.normal(k, (3,)),
                   "c": jnp.zeros((2,))}, keys)
    assert st["w"].shape == (6, 3)
    assert st["c"].shape == (6, 2)
    assert len({tuple(np.asarray(st["w"][i])) for i in range(6)}) == 6


# ---------------------------------------------------------------------------
# sampled-cohort round ≡ standalone cohort (the tentpole parity claim)
# ---------------------------------------------------------------------------


def _toy_cohort(n, seed=0):
    from repro.optim import sgd

    def loss_fn(tr, batch):
        return jnp.mean((tr["shared"]["w"].sum() + tr["local"]["v"].sum()
                         - batch["tgt"]) ** 2)

    opt = sgd(1e-2)

    def local_step(tr, op, batch):
        loss, grads = jax.value_and_grad(loss_fn)(tr, batch)
        upd, op = opt.update(grads, op, tr)
        return jax.tree_util.tree_map(lambda p, u: p + u, tr, upd), op, loss

    rng = np.random.RandomState(seed)
    mk = lambda: {"shared": {"w": rng.randn(3).astype(np.float32)},
                  "local": {"v": rng.randn(2).astype(np.float32)}}
    stacked = trees.stack([mk() for _ in range(n)])
    return local_step, opt, stacked, rng


def test_sampled_round_matches_standalone_cohort():
    """Gather K rows from an N-client store, run the fused robust round,
    scatter back — the sampled rows must equal the same K clients run as a
    standalone n_clients=K stack (same compiled program, same inputs: the
    store adds nothing numerically).  ≤1e-6 required; bitwise expected."""
    from repro.core.cohort import build_supervised_round

    N, K = 24, 4
    local_step, opt, stacked, rng = _toy_cohort(N)
    st_op = stacked_client_init(
        lambda k: opt.init({"shared": {"w": jnp.zeros(3)},
                            "local": {"v": jnp.zeros(2)}}),
        jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(0), i))(
            jnp.arange(N)))
    pend = jax.tree_util.tree_map(
        np.zeros_like, trees.select(stacked, lambda p: p.startswith("shared")))
    store = PopulationStore({"trainable": stacked, "opt": st_op,
                             "pending": pend})

    step = build_supervised_round(local_step,
                                  lambda p: p.startswith("shared"),
                                  donate=False, robust=True)
    ids = ClientSampler("uniform", N, K, seed=5).sample()
    batches = {"tgt": jnp.asarray(rng.randn(K, 2, 1), np.float32)}
    train = jnp.asarray([1.0, 0.0, 1.0, 1.0])     # client 1 straggles
    aggw = jnp.asarray([1.0, 0.5, 1.0, 1.0])
    recv = rej = None
    recv, rej, ontime = jnp.ones(K), jnp.zeros(K), jnp.ones(K)

    # standalone reference: the K clients as their own cohort
    ref_tr = jax.tree_util.tree_map(jnp.asarray,
                                    store.gather("trainable", ids))
    ref_op = jax.tree_util.tree_map(jnp.asarray, store.gather("opt", ids))
    ref_pd = jax.tree_util.tree_map(jnp.asarray,
                                    store.gather("pending", ids))
    ref = step(ref_tr, ref_op, ref_pd, batches, train, aggw, recv, rej,
               ontime)

    # population path: gather → round → scatter → read the rows back
    tr_d = jax.tree_util.tree_map(jnp.asarray,
                                  store.gather("trainable", ids))
    op_d = jax.tree_util.tree_map(jnp.asarray, store.gather("opt", ids))
    pd_d = jax.tree_util.tree_map(jnp.asarray, store.gather("pending", ids))
    out = step(tr_d, op_d, pd_d, batches, train, aggw, recv, rej, ontime)
    store.scatter("trainable", ids, out[0])
    store.scatter("opt", ids, out[1])
    store.scatter("pending", ids, out[2])

    got_tr = store.gather("trainable", ids)
    for k, leaf in trees.flatten(ref[0]).items():
        np.testing.assert_allclose(np.asarray(leaf),
                                   trees.flatten(got_tr)[k], atol=1e-6,
                                   err_msg=k)
    got_pd = store.gather("pending", ids)
    for k, leaf in trees.flatten(ref[2]).items():
        np.testing.assert_allclose(np.asarray(leaf),
                                   trees.flatten(got_pd)[k], atol=1e-6,
                                   err_msg=k)


# ---------------------------------------------------------------------------
# PopulationData: pure-function draws
# ---------------------------------------------------------------------------


def _toy_pool(n=64, n_classes=4, seed=0):
    r = np.random.RandomState(seed)
    return {"tokens": r.randint(0, 100, (n, 8)).astype(np.int32),
            "label": np.arange(n) % n_classes}


def test_population_data_draws_are_pure():
    probs = np.full((4, 4), 0.25)
    d1 = PopulationData(_toy_pool(), probs, seed=3)
    d2 = PopulationData(_toy_pool(), probs, seed=3)
    b1 = d1.round_batches(2, 7, local_steps=2, batch=4)
    # consumption order doesn't matter: draw other clients/rounds first
    d2.round_batches(0, 0, 2, 4)
    d2.test_set(2, 8)
    b2 = d2.round_batches(2, 7, local_steps=2, batch=4)
    for x, y in zip(b1, b2):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_population_data_respects_class_probs():
    probs = np.zeros((2, 4))
    probs[0, 1] = 1.0            # client 0 only ever sees class 1
    probs[1] = 0.25
    d = PopulationData(_toy_pool(256), probs, seed=0)
    for b in d.round_batches(0, 0, local_steps=4, batch=16):
        assert np.all(b["label"] == 1)


def test_population_data_test_set_disjoint_stream():
    probs = np.full((1, 4), 0.25)
    d = PopulationData(_toy_pool(), probs, seed=0)
    te = d.test_set(0, 16)
    te2 = d.test_set(0, 16)
    np.testing.assert_array_equal(te["tokens"], te2["tokens"])


# ---------------------------------------------------------------------------
# Scenario traces
# ---------------------------------------------------------------------------


def test_scenario_inert_default():
    s = Scenario()
    assert s.is_inert()
    tr = s.realize(8, 5)
    np.testing.assert_array_equal(tr.avail, 1.0)
    np.testing.assert_array_equal(tr.gain_scale, 1.0)
    np.testing.assert_allclose(tr.class_probs, 0.25)


def test_scenario_dirichlet_noniid():
    tr = Scenario(alpha=0.1, seed=1).realize(100, 3)
    assert tr.class_probs.shape == (100, 4)
    np.testing.assert_allclose(tr.class_probs.sum(1), 1.0, atol=1e-9)
    # α=0.1 is strongly skewed: the dominant class carries far more mass
    # than the IID 0.25
    assert tr.class_probs.max(1).mean() > 0.6


def test_scenario_axes_are_independent_draw_blocks():
    """Enabling availability must not perturb the Dirichlet draw (fixed
    per-axis block order in realize)."""
    a = Scenario(alpha=0.1, seed=2).realize(32, 4)
    b = Scenario(alpha=0.1, avail="diurnal", seed=2).realize(32, 4)
    np.testing.assert_array_equal(a.class_probs, b.class_probs)


def test_scenario_horizon_prefix_stable():
    """Re-realizing with a longer horizon reproduces the shorter run's
    rows (kill/resume emulates the kill by running fewer rounds)."""
    s = Scenario(alpha=0.1, avail="diurnal", mobility="waypoint", seed=1)
    a, b = s.realize(16, 3), s.realize(16, 9)
    np.testing.assert_array_equal(a.class_probs, b.class_probs)
    np.testing.assert_array_equal(a.avail, b.avail[:3])
    np.testing.assert_array_equal(a.avail_p, b.avail_p[:3])
    np.testing.assert_array_equal(a.gain_scale, b.gain_scale[:3])


def test_scenario_diurnal_availability_bounds():
    s = Scenario(avail="diurnal", avail_period=8, avail_min=0.05, seed=0)
    tr = s.realize(16, 32)
    assert tr.avail_p.min() >= 0.05 - 1e-12
    assert tr.avail_p.max() <= 1.0 + 1e-12
    assert set(np.unique(tr.avail)) <= {0.0, 1.0}
    # a diurnal population is not always-on
    assert 0.0 < tr.avail.mean() < 1.0


def test_scenario_periodic_duty_cycle():
    s = Scenario(avail="periodic", avail_period=4, avail_duty=0.5, seed=0)
    tr = s.realize(64, 16)
    assert abs(tr.avail_p.mean() - 0.5) < 0.2


def test_scenario_waypoint_gains():
    s = Scenario(mobility="waypoint", seed=4)
    tr = s.realize(32, 10)
    assert tr.gain_scale.shape == (10, 32)
    assert tr.gain_scale.min() > 0.0
    assert tr.gain_scale.max() <= 1.0 + 1e-6       # unit gain inside ref_m
    # clients move: per-client gains change over rounds
    assert np.abs(np.diff(tr.gain_scale, axis=0)).max() > 0.0


def test_scenario_trace_clamps_past_horizon():
    tr = Scenario(avail="diurnal", mobility="waypoint", seed=0).realize(4, 3)
    np.testing.assert_array_equal(tr.avail_round(99), 1.0)
    np.testing.assert_array_equal(tr.gain_round(99), 1.0)
    np.testing.assert_array_equal(tr.avail_probs(99), 1.0)


def test_scenario_from_spec_roundtrip():
    s = Scenario.from_spec("alpha=0.1,avail=diurnal,avail_period=8,"
                           "mobility=waypoint,seed=3")
    assert s.alpha == 0.1 and s.avail == "diurnal" and s.seed == 3
    assert Scenario.from_dict(s.to_dict()) == s
    assert Scenario.from_spec(None) is None
    assert Scenario.from_spec("none") is None
    assert math.isinf(Scenario.from_spec("alpha=inf").alpha)


def test_scenario_from_spec_unknown_key_raises():
    with pytest.raises(ValueError):
        Scenario.from_spec("alpha=0.1,warp=9")
    with pytest.raises(ValueError):
        Scenario.from_dict({"alpha": 0.1, "warp": 9})
    with pytest.raises(ValueError):
        Scenario(avail="sometimes")


# ---------------------------------------------------------------------------
# end-to-end: population PFTT determinism + resume (the fused stack)
# ---------------------------------------------------------------------------

POP_KW = dict(rounds=3, local_steps=2, batch=4, pretrain_steps=10,
              samples_per_client=32, test_samples=8, d_model=32,
              lora_rank=2, adapter_dim=4, seed=0, verbose=False)


def _pop_cfg(tmp_path=None, resume=False, rounds=3, **kw):
    from repro.core.pftt import PFTTConfig
    pop = PopulationConfig(
        population=16, cohort_size=4, sampler="availability",
        scenario=Scenario(alpha=0.1, avail="diurnal", avail_period=6,
                          mobility="waypoint", seed=1))
    base = dict(POP_KW, rounds=rounds, **kw)
    return PFTTConfig(population=pop,
                      ckpt_dir=None if tmp_path is None else str(tmp_path),
                      resume=resume, **base)


@pytest.mark.slow
def test_population_pftt_deterministic():
    from repro.core.pftt import run_pftt
    a = run_pftt(_pop_cfg())
    b = run_pftt(_pop_cfg())
    np.testing.assert_array_equal(a["acc_per_round"], b["acc_per_round"])
    assert a["total_bytes"] == b["total_bytes"]
    assert 0.0 < a["participation_frac"] <= 1.0


@pytest.mark.slow
def test_population_pftt_kill_resume_exact(tmp_path):
    """A run killed after 2 of 4 rounds and resumed must reproduce the
    uninterrupted run exactly: store + global from the npz, sampler RNG /
    tracker / flags from the sidecar, channel draws burned."""
    from repro.core.pftt import run_pftt
    full = run_pftt(_pop_cfg(rounds=4))
    run_pftt(_pop_cfg(tmp_path, rounds=2))              # "killed" after 2
    res = run_pftt(_pop_cfg(tmp_path, resume=True, rounds=4))
    np.testing.assert_array_equal(full["acc_per_round"],
                                  res["acc_per_round"])
    assert full["total_bytes"] == res["total_bytes"]


def test_population_pfit_rejects_full_tree_methods():
    from repro.core.pfit import PFITConfig, run_pfit
    cfg = PFITConfig(rounds=1, population=PopulationConfig(
        population=8, cohort_size=2), method="pfit")
    with pytest.raises(ValueError):
        run_pfit(cfg)
