"""Run telemetry (repro.obs): JSONL schema, span tracer, on-device
health parity, and the kill/resume event-stream contract.

The contract under test:

* ``RunTelemetry`` writes one JSON object per line; a written stream
  reads back equal (NaN sanitized to null), validates clean, and the
  validator catches out-of-order / duplicate / schema-less streams.
* ``SpanTracer`` accumulates per-phase seconds whether or not Chrome
  recording is on; recorded "X" events nest by time containment (a
  child's [ts, ts+dur] interval lies inside its parent's).
* The health scalars computed INSIDE the fused round body match a
  float64 host recomputation from the same inputs to ≤1e-6 — and
  enabling them does not perturb the round's state outputs.
* A population run killed after 2 of 4 rounds and resumed reproduces
  the uninterrupted run's canonical event stream byte-for-byte
  (round events, ``wall`` stripped).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import trees
from repro.obs import (HEALTH_KEYS, RunTelemetry, SpanTracer,
                       canonical_stream, cohort_health, host_health,
                       read_events, validate_events)

# ---------------------------------------------------------------------------
# JSONL schema round-trip + validation
# ---------------------------------------------------------------------------


def _write_stream(tmp_path, rounds=3):
    tele = RunTelemetry(str(tmp_path))
    tele.start({"mode": "test", "rounds": rounds})
    for r in range(rounds):
        tele.round_event(r, {
            "acc": 0.5 + 0.1 * r,
            "cohort": [r, r + 1],
            "comm": {"record_id": r, "round": r, "bytes": 1000 * (r + 1),
                     "delay_s": float("nan") if r == 1 else 0.25,
                     "outages": 0},
            "staleness": {"pending": 0, "abandoned": 0,
                          "retransmissions": 0, "quorum_noops": 0},
            "health": {k: 0.1 for k in HEALTH_KEYS},
        }, wall={"phases": {"device-step": 0.01 * (r + 1)}})
        tele.checkpoint(r)
    return tele


def test_jsonl_round_trip_and_validate(tmp_path):
    tele = _write_stream(tmp_path)
    events = read_events(tele.path)
    assert validate_events(events) == []
    assert [e["event"] for e in events] == \
        ["run", "round", "checkpoint", "round", "checkpoint",
         "round", "checkpoint"]
    rounds = [e for e in events if e["event"] == "round"]
    # NaN is not JSON: the all-outage round's delay must read back None
    assert rounds[1]["comm"]["delay_s"] is None
    assert rounds[0]["comm"]["delay_s"] == 0.25
    assert rounds[2]["health"]["update_norm"] == pytest.approx(0.1)
    # canonical stream is deterministic and wall-free
    canon = canonical_stream(events)
    assert len(canon) == 3
    assert all("wall" not in json.loads(c) for c in canon)
    assert canon == canonical_stream(read_events(tele.path))


def test_validator_catches_bad_streams(tmp_path):
    assert validate_events([]) == ["empty event stream"]
    # missing run header
    assert any("expected 'run'" in e for e in validate_events(
        [{"event": "round", "round": 0, "comm": {}, "wall": {}}]))
    # wrong schema version
    assert any("schema version" in e for e in validate_events(
        [{"event": "run", "schema": 999, "meta": {}}]))
    ok = [{"event": "run", "schema": 1, "meta": {}},
          {"event": "round", "round": 1, "comm": {}, "wall": {}},
          {"event": "round", "round": 0, "comm": {}, "wall": {}}]
    assert any("out of order" in e for e in validate_events(ok))
    dup = [{"event": "run", "schema": 1, "meta": {}},
           {"event": "round", "round": 0, "comm": {}, "wall": {}},
           {"event": "round", "round": 0, "comm": {}, "wall": {}}]
    assert any("duplicate round 0" in e for e in validate_events(dup))
    missing = [{"event": "run", "schema": 1, "meta": {}},
               {"event": "round", "round": 0, "wall": {}}]
    assert any("missing 'comm'" in e for e in validate_events(missing))
    assert any("unknown type" in e for e in validate_events(
        [{"event": "run", "schema": 1, "meta": {}}, {"event": "warp"}]))


def test_disabled_telemetry_is_a_noop(tmp_path):
    tele = RunTelemetry(None)
    assert not tele.enabled
    tele.start({})
    tele.round_event(0, {"comm": {}})
    tele.checkpoint(0)
    tele.close()   # nothing written anywhere


# ---------------------------------------------------------------------------
# span tracer: accumulation, nesting, Chrome trace shape
# ---------------------------------------------------------------------------


def test_tracer_accumulates_even_when_disabled():
    tr = SpanTracer(enabled=False)
    with tr.span("round") as sp:
        with tr.span("gather"):
            pass
    assert sp.dur >= 0.0
    phases = tr.pop_round()
    assert set(phases) == {"round", "gather"}
    assert phases["round"] >= phases["gather"] >= 0.0
    assert tr.pop_round() == {}                    # reset on pop
    assert set(tr.totals()) == {"round", "gather"}  # totals never reset
    assert tr.chrome_trace()["traceEvents"] == []   # nothing recorded


def test_tracer_chrome_events_nest_and_order(tmp_path):
    tr = SpanTracer(enabled=True)
    with tr.span("round"):
        with tr.span("gather"):
            pass
        with tr.span("device-step", rnd=3):
            pass
    with tr.span("eval"):
        pass
    ev = tr.chrome_trace()["traceEvents"]
    assert [e["name"] for e in ev] == \
        ["gather", "device-step", "round", "eval"]   # closed-order append
    by = {e["name"]: e for e in ev}
    # children lie inside the parent interval (Perfetto nesting rule)
    rnd = by["round"]
    for child in ("gather", "device-step"):
        c = by[child]
        assert c["ts"] >= rnd["ts"]
        assert c["ts"] + c["dur"] <= rnd["ts"] + rnd["dur"] + 1e-3
    assert by["eval"]["ts"] >= rnd["ts"] + rnd["dur"] - 1e-3
    assert by["device-step"]["args"] == {"rnd": 3}
    assert all(e["ph"] == "X" and e["tid"] == 1 for e in ev)
    # write() produces a loadable JSON object file
    p = tmp_path / "trace.json"
    tr.write(str(p))
    with open(p) as f:
        assert json.load(f)["traceEvents"] == ev


# ---------------------------------------------------------------------------
# health scalars: engine output vs float64 host oracle
# ---------------------------------------------------------------------------


def _toy_round(health, seed=0):
    """The population bench's toy workload through the robust fused round."""
    from repro.core.cohort import build_supervised_round
    from repro.optim import sgd

    C = 4
    opt = sgd(1e-2)

    def loss_fn(tr, batch):
        return jnp.mean((tr["shared"]["w"].sum() + tr["local"]["v"].sum()
                         - batch["tgt"]) ** 2)

    def local_step(tr, op, batch):
        loss, grads = jax.value_and_grad(loss_fn)(tr, batch)
        upd, op = opt.update(grads, op, tr)
        return jax.tree_util.tree_map(lambda p, u: p + u, tr, upd), op, loss

    rng = np.random.RandomState(seed)
    stacked = trees.stack(
        [{"shared": {"w": rng.randn(3).astype(np.float32)},
          "local": {"v": rng.randn(2).astype(np.float32)}}
         for _ in range(C)])
    opt0 = opt.init(jax.tree_util.tree_map(jnp.zeros_like,
                                           trees.unstack(stacked, C)[0]))
    st_op = jax.tree_util.tree_map(
        lambda l: np.broadcast_to(np.asarray(l), (C,) + np.shape(l)).copy(),
        opt0)
    pend = jax.tree_util.tree_map(
        np.zeros_like, trees.select(stacked,
                                    lambda p: p.startswith("shared")))
    step = build_supervised_round(local_step,
                                  lambda p: p.startswith("shared"),
                                  donate=False, robust=True, health=health)
    batches = {"tgt": jnp.asarray(rng.randn(C, 2, 1), np.float32)}
    ones, zeros = jnp.ones(C), jnp.zeros(C)
    w = jnp.asarray([1.0, 0.5, 0.25, 0.0])
    # (train_m, agg_w, recv_m, rejoin_m, ontime_m)
    margs = (ones, w, ones, zeros, ones)
    outs = step(jax.tree_util.tree_map(jnp.asarray, stacked),
                jax.tree_util.tree_map(jnp.asarray, st_op),
                jax.tree_util.tree_map(jnp.asarray, pend), batches, *margs)
    return stacked, w, outs


def test_health_parity_vs_host_oracle():
    stacked, w, outs = _toy_round(health=True)
    st_tr, _, send, losses, hstats = outs
    assert set(hstats) == set(HEALTH_KEYS)
    ref = trees.select(stacked, lambda p: p.startswith("shared"))
    oracle = host_health(send, ref, losses, w, 1.0)
    for k in HEALTH_KEYS:
        assert float(hstats[k]) == pytest.approx(oracle[k], abs=1e-6), k
    # sanity on magnitudes: 3 of 4 clients delivered, every row trained
    assert float(hstats["delivered"]) == 3.0
    assert float(hstats["agg_weight_sum"]) == pytest.approx(1.75)
    assert float(hstats["update_norm"]) > 0.0
    assert float(hstats["codec_err"]) == 0.0        # no codec in this round


def test_health_output_does_not_perturb_the_round():
    _, _, base = _toy_round(health=False)
    _, _, with_h = _toy_round(health=True)
    assert len(with_h) == len(base) + 1
    for a, b in zip(jax.tree_util.tree_leaves(base[:4]),
                    jax.tree_util.tree_leaves(with_h[:4])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cohort_health_off_mesh_matches_oracle_with_codec():
    rng = np.random.RandomState(3)
    send = {"w": jnp.asarray(rng.randn(4, 3), np.float32)}
    ref = {"w": jnp.asarray(rng.randn(4, 3), np.float32)}
    raw = {"w": jnp.asarray(rng.randn(4, 3), np.float32)}
    dec = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jnp.asarray(rng.randn(4, 3), np.float32), raw)
    losses = jnp.asarray(rng.rand(4, 2), np.float32)
    w = jnp.asarray([1.0, 1.0, 0.0, 0.5])
    out = cohort_health(send, ref, losses, w, jnp.float32(1.0),
                        raw=raw, decoded=dec)
    oracle = host_health(send, ref, losses, w, 1.0, raw=raw, decoded=dec)
    for k in HEALTH_KEYS:
        assert float(out[k]) == pytest.approx(oracle[k], abs=1e-6), k
    assert float(out["codec_err"]) > 0.0


# ---------------------------------------------------------------------------
# kill/resume: canonical event stream byte-identity (population PFTT)
# ---------------------------------------------------------------------------

POP_KW = dict(local_steps=2, batch=4, pretrain_steps=10,
              samples_per_client=32, test_samples=8, d_model=32,
              lora_rank=2, adapter_dim=4, seed=0, verbose=False)


def _pop_cfg(tele_dir, ckpt_dir=None, resume=False, rounds=4):
    from repro.core.pftt import PFTTConfig
    from repro.fl.population import PopulationConfig
    from repro.obs import TelemetryConfig
    from repro.wireless.scenarios import Scenario
    pop = PopulationConfig(
        population=16, cohort_size=4, sampler="availability",
        scenario=Scenario(alpha=0.1, avail="diurnal", avail_period=6,
                          mobility="waypoint", seed=1))
    return PFTTConfig(population=pop, rounds=rounds,
                      ckpt_dir=None if ckpt_dir is None else str(ckpt_dir),
                      resume=resume,
                      telemetry=TelemetryConfig(out_dir=str(tele_dir)),
                      **POP_KW)


@pytest.mark.slow
def test_population_kill_resume_event_stream_exact(tmp_path):
    """Killed after 2 of 4 rounds + resumed → the canonical stream
    (round events, wall stripped) is byte-identical to the uninterrupted
    run's, and both validate clean."""
    from repro.core.pftt import run_pftt

    run_pftt(_pop_cfg(tmp_path / "full", rounds=4))
    full = read_events(tmp_path / "full" / "events.jsonl")

    kdir = tmp_path / "killed"
    run_pftt(_pop_cfg(kdir, ckpt_dir=tmp_path / "ck", rounds=2))
    run_pftt(_pop_cfg(kdir, ckpt_dir=tmp_path / "ck", resume=True,
                      rounds=4))
    resumed = read_events(kdir / "events.jsonl")

    assert validate_events(full) == []
    assert validate_events(resumed) == []
    assert sum(1 for e in resumed if e["event"] == "resume") == 1
    cf, cr = canonical_stream(full), canonical_stream(resumed)
    assert len(cf) == 4
    assert cf == cr
