"""Continuous-time robust rounds: channel-driven deadlines, retransmission
backoff, payload integrity, and the min-quorum gate.

Unit layer: the ``StalenessTracker`` in deadline mode is a pure host-side
function of trace masks + realized gains + known payload sizes, so every
semantic (deadline miss → pending, capped exponential backoff, retry
exhaustion, checksum NACK, quorum no-op) is pinned directly on tiny arrays.
Integration layer: engine-vs-legacy-loop parity under the FULL fault mix
(dropout + straggle + crash + SNR dip + corruption) with a finite deadline,
and bitwise equivalence of the inert config with the round-granular runtime.
"""
import json
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comms import payload_checksum
from repro.core.robust import RoundPlan, StalenessConfig, StalenessTracker
from repro.wireless import (ArrivalModel, DeadlineConfig, FaultPlan,
                            RayleighChannel)
from repro.wireless.faults import RoundFaults


# ---------------------------------------------------------------------------
# payload integrity: host-side checksum
# ---------------------------------------------------------------------------

def test_payload_checksum_detects_flip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"w": jnp.ones(4, jnp.float32)}}
    ref = payload_checksum(tree)
    assert ref == payload_checksum(tree)          # deterministic
    flipped = {"a": tree["a"].at[1, 2].add(1e-3), "b": tree["b"]}
    assert payload_checksum(flipped) != ref       # single-element corruption
    renamed = {"a2": tree["a"], "b": tree["b"]}
    assert payload_checksum(renamed) != ref       # path is part of the sum
    assert 0 <= ref <= 0xFFFFFFFF


# ---------------------------------------------------------------------------
# spec parsing (satellite: unknown keys must raise, not silently ignore)
# ---------------------------------------------------------------------------

def test_fault_plan_from_spec_unknown_key_raises():
    with pytest.raises(ValueError) as ei:
        FaultPlan.from_spec("dropout_p=0.1,bogus_knob=3")
    assert "bogus_knob" in str(ei.value)
    assert "dropout_p" in str(ei.value)           # lists the valid keys
    with pytest.raises(ValueError):
        FaultPlan.from_dict({"corrupt_p": 0.1, "nope": 1})
    # the corruption knob itself is a valid key
    assert FaultPlan.from_spec("corrupt_p=0.25").corrupt_p == 0.25


def test_deadline_config_from_spec():
    assert DeadlineConfig.from_spec(None) is None
    assert DeadlineConfig.from_spec("none") is None
    cfg = DeadlineConfig.from_spec("deadline_s=0.5,min_quorum=2,seed=7")
    assert cfg.deadline_s == 0.5 and cfg.min_quorum == 2 and cfg.seed == 7
    assert not cfg.is_inert()
    assert DeadlineConfig().is_inert()
    assert math.isinf(DeadlineConfig.from_spec("deadline_s=inf").deadline_s)
    with pytest.raises(ValueError):
        DeadlineConfig.from_spec("deadline_s=0.5,bogus=1")
    rt = DeadlineConfig.from_dict(cfg.to_dict())
    assert rt == cfg


def test_deadline_config_from_json_file(tmp_path):
    p = tmp_path / "dl.json"
    p.write_text(json.dumps({"deadline_s": 1.5, "backoff_base_s": 0.1}))
    cfg = DeadlineConfig.from_spec(str(p))
    assert cfg.deadline_s == 1.5 and cfg.backoff_base_s == 0.1


# ---------------------------------------------------------------------------
# tracker semantics in deadline mode (pure host-side units)
# ---------------------------------------------------------------------------

N = 3


def _tracker(dl, **cfg_kw):
    ch = RayleighChannel(mean_snr_db=5.0, seed=0)
    arr = ArrivalModel(ch, dl, N)
    tr = StalenessTracker(N, StalenessConfig(**cfg_kw), deadline=dl,
                          arrivals=arr)
    return tr, arr, ch


def _faults(train, tx=None, corrupt=None):
    train = np.asarray(train, np.float32)
    one = np.ones(N, np.float32)
    return RoundFaults(train=train, tx=one if tx is None else
                       np.asarray(tx, np.float32),
                       recv=one, rejoin=np.zeros(N, np.float32),
                       gain_scale=one,
                       corrupt=None if corrupt is None else
                       np.asarray(corrupt, np.float32),
                       compute_scale=None)


def test_deadline_requires_arrival_model():
    with pytest.raises(ValueError):
        StalenessTracker(N, deadline=DeadlineConfig(deadline_s=1.0))


def test_arrival_time_is_bits_over_realized_rate():
    dl = DeadlineConfig(deadline_s=1.0)
    tr, arr, ch = _tracker(dl)
    gains = np.asarray([1.0, 1.0, 1.0])
    bits = np.asarray([1e3, 1e6, 1e12], np.float64)
    plan = tr.begin_round(_faults([1, 1, 1]), np.ones(N), gains=gains,
                          fresh_bits=bits)
    np.testing.assert_allclose(np.asarray(plan.arrival_s),
                               bits / arr.rates(gains))
    # the huge payload misses the deadline, the small ones make it
    assert plan.ontime[0] == 1.0 and plan.ontime[2] == 0.0
    assert plan.delivered[2] == 0.0 and plan.agg_w[2] == 0.0
    # pre-deadline weights × ontime == final pre-quorum weights
    np.testing.assert_array_equal(
        np.asarray(plan.agg_w_pre) * np.asarray(plan.ontime),
        np.asarray(plan.agg_w))
    # round duration is the deadline when it is finite
    assert plan.sim_dt_s == 1.0


def test_deadline_miss_goes_pending_and_backs_off():
    dl = DeadlineConfig(deadline_s=1.0, backoff_base_s=2.0)
    tr, arr, _ = _tracker(dl, a=0.5, max_staleness=4)
    gains = np.ones(N)
    bits = np.asarray([1e3, 1e3, 1e12], np.float64)
    plan = tr.begin_round(_faults([1, 1, 1]), np.ones(N), gains=gains,
                          fresh_bits=bits)
    charged = tr.end_round(plan, bits)
    # the miss is charged (it transmitted) but buffered for retransmission
    assert charged[2] == bits[2]
    assert tr.valid[2] and not tr.valid[0]
    assert tr.fails[2] == 1 and tr.fails[0] == 0
    # first failure waits base·2^0 from the round's end
    assert tr.next_try_s[2] == tr.now_s + 2.0
    # next round: client 2 straggles (train=0) → its pending payload is
    # backoff-gated: 2s wait > 1s deadline → it cannot even attempt
    plan2 = tr.begin_round(_faults([1, 1, 0]), np.ones(N), gains=gains,
                           fresh_bits=bits)
    assert plan2.attempt[2] == 0.0
    tr.end_round(plan2, bits)
    assert tr.fails[2] == 1          # no attempt → no new failure
    # after enough rounds the backoff window opens and it retries
    for _ in range(4):
        p = tr.begin_round(_faults([1, 1, 0]), np.ones(N), gains=gains,
                           fresh_bits=bits)
        tr.end_round(p, bits)
        if p.attempt[2] > 0:
            break
    else:
        pytest.fail("backoff window never opened")


def test_retry_exhaustion_drops_pending_bits_from_ledger():
    dl = DeadlineConfig(deadline_s=10.0, max_retries=2)
    tr, _, _ = _tracker(dl, max_staleness=100)
    gains = np.ones(N)
    bits = np.full(N, 1e3, np.float64)
    outage = np.asarray([0.0, 1.0, 1.0])    # client 0 always outages
    total_charged = np.zeros(N)
    train = [1, 1, 1]
    for r in range(6):
        plan = tr.begin_round(_faults(train), outage, gains=gains,
                              fresh_bits=bits)
        total_charged += tr.end_round(plan, bits)
        train = [0, 1, 1]                   # client 0 never trains again
    # fresh attempt + max_retries retransmissions, then abandoned: the
    # pending payload's bits drop out of the ledger for good
    assert tr.abandoned == 1
    assert not tr.valid[0] and tr.bits[0] == 0.0 and tr.fails[0] == 0
    assert total_charged[0] == bits[0] * (1 + dl.max_retries)


def test_corrupt_and_outage_same_attempt_charges_once():
    dl = DeadlineConfig(deadline_s=10.0)
    tr, _, _ = _tracker(dl, max_staleness=4)
    gains = np.ones(N)
    bits = np.full(N, 1e3, np.float64)
    # client 0 is simultaneously corrupted AND in outage: one attempt, one
    # failure count, one charge
    plan = tr.begin_round(_faults([1, 1, 1], corrupt=[1, 0, 0]),
                          np.asarray([0.0, 1.0, 1.0]), gains=gains,
                          fresh_bits=bits)
    assert plan.attempt[0] == 1.0 and plan.delivered[0] == 0.0
    assert plan.corrupt[0] == 1.0
    charged = tr.end_round(plan, bits)
    assert charged[0] == bits[0]            # exactly one airtime charge
    assert tr.fails[0] == 1                 # not double-counted
    # a corrupted-but-otherwise-clean delivery is NACKed, never merged
    plan2 = tr.begin_round(_faults([1, 1, 1], corrupt=[1, 0, 0]),
                           np.ones(N), gains=gains, fresh_bits=bits)
    assert plan2.delivered[0] == 0.0 and plan2.agg_w[0] == 0.0
    assert plan2.delivered[1] == 1.0


def test_corruption_nacks_in_round_granular_mode_too():
    """Without a DeadlineConfig the corrupted delivery is still detected
    and dropped (checksum NACK ≈ outage) in the PR 6 tracker path."""
    tr = StalenessTracker(N, StalenessConfig(max_staleness=2))
    plan = tr.begin_round(_faults([1, 1, 1], corrupt=[0, 1, 0]), np.ones(N))
    assert plan.delivered[1] == 0.0 and plan.agg_w[1] == 0.0
    assert plan.delivered[0] == 1.0
    tr.end_round(plan, np.full(N, 8.0))
    assert tr.valid[1]                      # NACKed payload goes pending


def test_quorum_noop_nacks_deliveries_without_backoff():
    dl = DeadlineConfig(deadline_s=10.0, backoff_base_s=2.0, min_quorum=2)
    tr, _, _ = _tracker(dl, max_staleness=4)
    gains = np.ones(N)
    bits = np.full(N, 1e3, np.float64)
    # only one client delivers → under quorum → server voids the round
    plan = tr.begin_round(_faults([1, 1, 1]), np.asarray([1.0, 0.0, 0.0]),
                          gains=gains, fresh_bits=bits)
    assert plan.n_delivered == 1 and not plan.quorum_ok
    np.testing.assert_array_equal(np.asarray(plan.agg_w), np.zeros(N))
    np.testing.assert_array_equal(np.asarray(plan.delivered), np.zeros(N))
    charged = tr.end_round(plan, bits)
    # airtime was spent by every attempt, even though nothing merged
    np.testing.assert_array_equal(charged, bits)
    assert tr.quorum_noops == 1
    # the server's abort is not the channel's failure: no backoff penalty,
    # no failure counted, every payload retained as pending
    assert tr.fails[0] == 0 and tr.valid.all()
    np.testing.assert_array_equal(tr.next_try_s, np.zeros(N))
    # with enough deliveries the same tracker merges normally again
    plan2 = tr.begin_round(_faults([1, 1, 1]), np.ones(N), gains=gains,
                           fresh_bits=bits)
    assert plan2.quorum_ok and plan2.n_delivered == N


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_min_quorum_zero_inf_deadline_matches_plain_tracker(seed):
    """Property: an inf-deadline/no-backoff/no-compute/min_quorum=0 config
    resolves every round EXACTLY like the round-granular tracker under an
    arbitrary fault mix (the continuous-time round is a strict extension)."""
    rng = np.random.RandomState(seed)
    dl = DeadlineConfig()           # inert knobs, but force-run the deadline
    tr_d, _, _ = _tracker(dl, a=0.5, max_staleness=2)   # code path anyway
    tr_p = StalenessTracker(N, StalenessConfig(a=0.5, max_staleness=2))
    bits = np.full(N, 1e4, np.float64)
    for r in range(5):
        f = _faults(rng.randint(0, 2, N), tx=rng.randint(0, 2, N),
                    corrupt=rng.randint(0, 2, N))
        outage = rng.randint(0, 2, N).astype(np.float64)
        gains = rng.rand(N) + 0.1
        pd = tr_d.begin_round(f, outage, gains=gains, fresh_bits=bits)
        pp = tr_p.begin_round(f, outage)
        for field in ("train", "attempt", "delivered", "staleness", "agg_w",
                      "recv", "rejoin"):
            np.testing.assert_array_equal(
                np.asarray(getattr(pd, field)),
                np.asarray(getattr(pp, field)), err_msg=field)
        cd = tr_d.end_round(pd, bits)
        cp = tr_p.end_round(pp, bits)
        np.testing.assert_array_equal(cd, cp)
        np.testing.assert_array_equal(tr_d.valid, tr_p.valid)
        np.testing.assert_array_equal(tr_d.age, tr_p.age)


def test_tracker_state_roundtrip_deadline_fields():
    dl = DeadlineConfig(deadline_s=1.0, backoff_base_s=2.0)
    tr, _, _ = _tracker(dl, max_staleness=4)
    bits = np.asarray([1e3, 1e3, 1e12], np.float64)
    plan = tr.begin_round(_faults([1, 1, 1]), np.ones(N), gains=np.ones(N),
                          fresh_bits=bits)
    tr.end_round(plan, bits)
    tr2, _, _ = _tracker(dl, max_staleness=4)
    tr2.load_state_dict(json.loads(json.dumps(tr.state_dict())))
    np.testing.assert_array_equal(tr.fails, tr2.fails)
    np.testing.assert_array_equal(tr.next_try_s, tr2.next_try_s)
    assert tr.now_s == tr2.now_s and tr.abandoned == tr2.abandoned
    # old (pre-deadline) checkpoints still load
    tr3 = StalenessTracker(N)
    tr3.load_state_dict({"valid": [0] * N, "age": [0] * N,
                         "bits": [0.0] * N})
    assert tr3.now_s == 0.0


# ---------------------------------------------------------------------------
# integration: engine vs legacy loop under deadline + full fault mix
# ---------------------------------------------------------------------------

PFTT_KW = dict(n_clients=3, rounds=3, local_steps=2, pretrain_steps=20,
               samples_per_client=150, seed=0)
MIX = FaultPlan(dropout_p=0.25, straggle_p=0.3, max_straggle=2, crash_p=0.1,
                max_crash=1, snr_dip_p=0.2, corrupt_p=0.25, seed=5)
DL = DeadlineConfig(deadline_s=0.05, backoff_base_s=0.01, max_retries=3,
                    min_quorum=2, compute_mean_s=0.005, seed=11)


def _ledgers_equal(a, b):
    assert a["total_bytes"] == b["total_bytes"]
    assert a["total_energy_j"] == b["total_energy_j"]
    assert a["total_sim_time_s"] == b["total_sim_time_s"]
    assert a["quorum_noops"] == b["quorum_noops"]


def test_pftt_deadline_engine_matches_loop():
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(fault_plan=MIX, staleness_a=0.5, max_staleness=3, deadline=DL)
    legacy = run_pftt(PFTTConfig(engine=False, **PFTT_KW, **kw))
    fused = run_pftt(PFTTConfig(engine=True, **PFTT_KW, **kw))
    np.testing.assert_allclose(legacy["acc_per_round"],
                               fused["acc_per_round"], atol=1e-5)
    _ledgers_equal(legacy, fused)
    assert fused["total_sim_time_s"] > 0


def test_pftt_inert_deadline_bitwise_plain_robust():
    """deadline=DeadlineConfig() (inert) must be byte-for-byte the
    round-granular robust engine: same accs, same ledger records."""
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(fault_plan=MIX, staleness_a=0.5, max_staleness=3)
    plain = run_pftt(PFTTConfig(**PFTT_KW, **kw))
    inert = run_pftt(PFTTConfig(**PFTT_KW, deadline=DeadlineConfig(), **kw))
    assert plain["acc_per_round"] == inert["acc_per_round"]
    assert plain["total_bytes"] == inert["total_bytes"]
    assert plain["total_energy_j"] == inert["total_energy_j"]


def test_pftt_deadline_without_fault_plan():
    """A DeadlineConfig alone (no injected faults) activates the robust
    continuous-time round over the zero-fault trace."""
    from repro.core.pftt import PFTTConfig, run_pftt
    res = run_pftt(PFTTConfig(**PFTT_KW, max_staleness=3,
                              deadline=DeadlineConfig(deadline_s=0.05,
                                                      compute_mean_s=0.01)))
    assert res["total_sim_time_s"] == pytest.approx(0.05 * PFTT_KW["rounds"])


def test_pftt_deadline_codec_engine_matches_loop():
    """Deadline scheduling with compressed uplinks: the realized encoded
    size rolls into the next round's scheduling estimate on both paths."""
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(fault_plan=MIX, staleness_a=0.5, max_staleness=3, deadline=DL,
              uplink_codec="int8")
    legacy = run_pftt(PFTTConfig(engine=False, **PFTT_KW, **kw))
    fused = run_pftt(PFTTConfig(engine=True, **PFTT_KW, **kw))
    np.testing.assert_allclose(legacy["acc_per_round"],
                               fused["acc_per_round"], atol=1e-5)
    _ledgers_equal(legacy, fused)


PFIT_KW = dict(n_clients=3, rounds=2, rollout_batch=4, pretrain_steps=15,
               rm_steps=15, d_model=48, n_layers=2, gen_len=8, prompt_len=6,
               seed=0)


def test_pfit_shepherd_deadline_engine_matches_loop():
    from repro.core.pfit import PFITConfig, run_pfit
    kw = dict(method="shepherd", shepherd_steps=2, fault_plan=MIX,
              staleness_a=0.5, max_staleness=3, deadline=DL, **PFIT_KW)
    legacy = run_pfit(PFITConfig(engine=False, **kw))
    fused = run_pfit(PFITConfig(engine=True, **kw))
    np.testing.assert_allclose(legacy["reward_per_round"],
                               fused["reward_per_round"], atol=1e-3)
    _ledgers_equal(legacy, fused)


# ---------------------------------------------------------------------------
# checkpoint: atomic writes (kill-during-write leaves the old file intact)
# ---------------------------------------------------------------------------

def test_checkpoint_atomic_kill_during_write(tmp_path, monkeypatch):
    from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
    path = str(tmp_path / "state.npz")
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    save_checkpoint(path, tree)
    ref = np.asarray(load_checkpoint(path, tree)["w"])

    real_savez = np.savez

    def dying_savez(f, **arrays):       # simulate a kill mid-serialization
        f.write(b"\x00garbage")
        raise KeyboardInterrupt

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(path, {"w": jnp.full(4, 9.0)})
    monkeypatch.setattr(np, "savez", real_savez)
    # the previous checkpoint is untouched and no tmp litter remains
    np.testing.assert_array_equal(
        np.asarray(load_checkpoint(path, tree)["w"]), ref)
    assert not any(fn.endswith(".tmp") for fn in os.listdir(tmp_path))


def test_pftt_deadline_resume_matches_uninterrupted(tmp_path):
    """Kill-and-resume under the continuous-time round: the tracker state,
    arrival draws and scheduling estimates all replay exactly."""
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(**PFTT_KW, fault_plan=MIX, staleness_a=0.5, max_staleness=3,
              deadline=DL)
    full = run_pftt(PFTTConfig(**kw))
    d = str(tmp_path / "ck")
    run_pftt(PFTTConfig(**{**kw, "rounds": 2}, ckpt_dir=d))
    resumed = run_pftt(PFTTConfig(**kw, ckpt_dir=d, resume=True))
    np.testing.assert_allclose(full["acc_per_round"],
                               resumed["acc_per_round"], atol=1e-6)
    assert full["total_bytes"] == resumed["total_bytes"]
    assert full["total_sim_time_s"] == \
        pytest.approx(resumed["total_sim_time_s"])
