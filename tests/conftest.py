"""Shared test fixtures/shims.

``hypothesis`` is an optional dependency: the property tests in
``test_properties.py`` / ``test_async_agg.py`` use it when available, but
the offline container does not ship it.  Rather than failing both modules
at collection (which also hides their plain, non-property tests), install a
minimal stand-in that turns every ``@given`` test into a skipped placeholder
while leaving the rest of the module runnable.
"""
import sys
import types

try:
    import hypothesis  # noqa: F401  (real library present — nothing to do)
except ImportError:
    import pytest

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for any strategy expression built at import time."""
        def __call__(self, *_a, **_k):
            return self

        def __getattr__(self, _name):
            return self

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda _name: _AnyStrategy()

    shim = types.ModuleType("hypothesis")
    shim.given = given
    shim.settings = settings
    shim.strategies = strategies
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
