"""Shared test fixtures/shims.

``hypothesis`` is an optional dependency (the ``dev`` extra installs it and
CI runs with the real library).  The offline container does not ship it, so
instead of skipping every property test we install a minimal deterministic
stand-in: each ``@given`` test runs ``max_examples`` generated examples
(capped) from a seed derived from the test name — the boundary example of
every strategy first, then pseudo-random draws.  Same strategies API subset
the test-suite uses (``integers``/``floats``/``booleans``/``tuples``/
``lists``/``sampled_from``); anything fancier should guard on the real
library.
"""

import sys
import types
import zlib

try:
    import hypothesis  # noqa: F401  (real library present — nothing to do)
except ImportError:
    import numpy as np

    _MAX_EXAMPLES_CAP = 20   # keep the offline runner tier-1-fast

    class _Strategy:
        def sample(self, rng, mode="random"):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def sample(self, rng, mode="random"):
            if mode == "min":
                return self.lo
            if mode == "max":
                return self.hi
            # randint half-open; avoid overflow on 2**31-1 bounds
            span = self.hi - self.lo
            return self.lo + int(rng.randint(0, span + 1)) if span < 2**31 \
                else self.lo + int(rng.random_sample() * span)

    class _Floats(_Strategy):
        def __init__(self, lo=-1e6, hi=1e6, allow_nan=False, width=64,
                     allow_infinity=False):
            self.lo, self.hi, self.width = float(lo), float(hi), width

        def sample(self, rng, mode="random"):
            if mode == "min":
                x = self.lo
            elif mode == "max":
                x = self.hi
            else:
                x = self.lo + rng.random_sample() * (self.hi - self.lo)
            if self.width == 32:   # stay inside the bounds after the cast
                x = float(np.clip(np.float32(x), self.lo, self.hi))
            return x

    class _Booleans(_Strategy):
        def sample(self, rng, mode="random"):
            if mode in ("min", "max"):
                return mode == "max"
            return bool(rng.randint(0, 2))

    class _SampledFrom(_Strategy):
        def __init__(self, elems):
            self.elems = list(elems)

        def sample(self, rng, mode="random"):
            if mode == "min":
                return self.elems[0]
            if mode == "max":
                return self.elems[-1]
            return self.elems[int(rng.randint(0, len(self.elems)))]

    class _Tuples(_Strategy):
        def __init__(self, *subs):
            self.subs = subs

        def sample(self, rng, mode="random"):
            return tuple(s.sample(rng, mode) for s in self.subs)

    class _Lists(_Strategy):
        def __init__(self, sub, min_size=0, max_size=10):
            self.sub, self.lo, self.hi = sub, min_size, max_size

        def sample(self, rng, mode="random"):
            if mode == "min":
                n = self.lo
            elif mode == "max":
                n = self.hi
            else:
                n = int(rng.randint(self.lo, self.hi + 1))
            return [self.sub.sample(rng, mode) for _ in range(n)]

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._mini_max_examples = max_examples
            return fn
        return deco

    def given(*strats, **kw_strats):
        assert not kw_strats, "mini-hypothesis: positional strategies only"

        def deco(fn):
            # no functools.wraps: __wrapped__ would make pytest read the
            # original signature and hunt for fixtures named after the
            # strategy arguments
            def runner():
                n = min(getattr(fn, "_mini_max_examples", None)
                        or _MAX_EXAMPLES_CAP, _MAX_EXAMPLES_CAP)
                rng = np.random.RandomState(
                    zlib.crc32(fn.__name__.encode()) % (2**31))
                for i in range(n):
                    mode = ("min", "max")[i] if i < 2 else "random"
                    args = [s.sample(rng, mode) for s in strats]
                    try:
                        fn(*args)
                    except Exception as e:
                        raise AssertionError(
                            f"{fn.__name__} falsified on example {i} "
                            f"({mode}): args={args!r}") from e
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _Integers
    strategies.floats = _Floats
    strategies.booleans = _Booleans
    strategies.sampled_from = _SampledFrom
    strategies.tuples = _Tuples
    strategies.lists = _Lists

    shim = types.ModuleType("hypothesis")
    shim.given = given
    shim.settings = settings
    shim.strategies = strategies
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
