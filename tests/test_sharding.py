"""Sharding policy unit tests + an actual 8-device SPMD execution
(subprocess so the host-device-count flag doesn't leak into other tests)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from jax.sharding import PartitionSpec as P

from repro.sharding import MeshCtx


def test_dim_axis_divisibility():
    mc = MeshCtx.single_device()
    assert mc.dim_axis(100, "model") is None  # size-1 axis → replicate


def test_spec_drops_nondivisible():
    # fake 4-device mesh via host platform is heavy; use the rule math with
    # a mesh dict stub through MeshCtx on 1 device (extent 1 → None) plus
    # direct unit check of the guard logic
    mc = MeshCtx.single_device()
    spec = mc.spec((10, 7), ("data", "model"))
    assert spec == P(None, None)


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import (MeshCtx, batch_specs, param_specs,
                                use_mesh, with_specs)
    from repro import trees

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mc = MeshCtx(mesh=mesh, batch_axes=("data",))
    cfg = get_config("{arch}").reduced(d_model=256, repeats=2)
    model = Model(cfg, meshctx=mc)
    params = model.init(jax.random.PRNGKey(0))
    pspecs = param_specs(mc, jax.eval_shape(lambda: params), cfg)
    params = jax.device_put(params, jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P)))
    B, S = 8, 64
    tokens = jnp.ones((B, S), jnp.int32)
    batch = dict(tokens=tokens, labels=tokens,
                 mask=jnp.ones((B, S)))
    bspecs = batch_specs(mc, jax.eval_shape(lambda: batch))
    batch = jax.device_put(batch, jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s), bspecs,
        is_leaf=lambda x: isinstance(x, P)))

    @jax.jit
    def loss_fn(p, b):
        return model.lm_loss(p, b)

    with use_mesh(mesh):
        l = loss_fn(params, batch)
    assert np.isfinite(float(l)), l
    # sharded value == single-device value
    mc1 = MeshCtx.single_device()
    model1 = Model(cfg, meshctx=mc1)
    l1 = model1.lm_loss(jax.device_get(params), jax.device_get(batch))
    np.testing.assert_allclose(float(l), float(l1), rtol=2e-4)
    print("SHARDED_OK", float(l))
""")


@pytest.mark.multidevice
@pytest.mark.slow
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "dbrx-132b",
                                  "mamba2-1.3b"])
def test_sharded_execution_matches_single_device(arch):
    """Run a real 8-device SPMD forward/loss and compare numerics against
    the single-device model — catches wrong psum/partial-softmax wiring."""
    import os
    code = SUBPROC.format(arch=arch)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=1800,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert "SHARDED_OK" in proc.stdout, proc.stderr[-3000:]


def test_param_specs_expert_sharding():
    import numpy as np
    from repro.configs import get_config
    from repro.sharding import param_specs
    from repro import trees as T

    cfg = get_config("dbrx-132b")
    mc = MeshCtx.single_device()  # axes size 1 → everything None, but rule
    shapes = {"stages": [{"layers": [{"ff": {
        "wg": jax.ShapeDtypeStruct((40, 16, 6144, 10752), jnp.bfloat16),
        "router": jax.ShapeDtypeStruct((6144, 16), jnp.float32)}}]}]}
    specs = param_specs(mc, shapes, cfg)
    # on a 1-device mesh all axes drop — just verify structure is preserved
    flat = T.flatten(specs)
    assert "stages/0/layers/0/ff/wg" in flat
