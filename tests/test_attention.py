"""Attention-core equivalences (jnp lowering paths used by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparseAttnConfig
from repro.models import attention as A


@pytest.fixture
def qkv():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    return (jax.random.normal(ks[0], (2, 256, 8, 32)),
            jax.random.normal(ks[1], (2, 256, 4, 32)),
            jax.random.normal(ks[2], (2, 256, 4, 32)))


@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("qb,kb", [(64, 64), (128, 32), (256, 256)])
def test_chunked_matches_dense(qkv, window, qb, kb):
    q, k, v = qkv
    want = A.dense_attention(q, k, v, causal=True, window=window)
    got = A.chunked_attention(q, k, v, causal=True, window=window,
                              q_block=qb, kv_block=kb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_sparse_matches_masked_dense(qkv):
    q, k, v = qkv
    scfg = SparseAttnConfig(block_size=16, local_blocks=2, sink_blocks=1,
                            stride=4)
    got = A.block_sparse_attention(q, k, v, scfg)
    idx, valid = A.sparse_block_table(16, 16, scfg)
    mask = np.zeros((256, 256), bool)
    for i in range(16):
        for a in range(idx.shape[1]):
            if valid[i, a]:
                j = idx[i, a]
                mask[i * 16:(i + 1) * 16, j * 16:(j + 1) * 16] = True
    mask &= np.tril(np.ones((256, 256), bool))
    want = A.dense_attention(q, k, v, causal=True, mask=jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_sparse_table_is_causal_and_covers_local_band():
    scfg = SparseAttnConfig(block_size=16, local_blocks=3, sink_blocks=1,
                            stride=4)
    idx, valid = A.sparse_block_table(32, 32, scfg)
    for i in range(32):
        active = set(idx[i, valid[i]])
        assert all(j <= i for j in active), "future block attended"
        assert 0 in active, "sink missing"
        for j in range(max(0, i - 2), i + 1):
            assert j in active, f"local band hole at q={i}, kv={j}"


def test_decode_matches_dense_single_query(qkv):
    q, k, v = qkv
    q1 = q[:, 100:101]
    want = A.dense_attention(q1, k[:, :101], v[:, :101], causal=True,
                             q_offset=100)
    got = A.decode_attention(q1, k, v, cache_len=101)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_decode_ring_window_equivalence():
    """A ring-buffered window cache must reproduce windowed attention."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    S, W = 96, 32
    q = jax.random.normal(ks[0], (1, S, 4, 16))
    k = jax.random.normal(ks[1], (1, S, 4, 16))
    v = jax.random.normal(ks[2], (1, S, 4, 16))
    want = A.dense_attention(q, k, v, causal=True, window=W)
    # simulate decoding with a ring cache of size W
    kc = jnp.zeros((1, W, 4, 16))
    vc = jnp.zeros((1, W, 4, 16))
    outs = []
    for t in range(S):
        slot = t % W
        kc = kc.at[:, slot].set(k[:, t])
        vc = vc.at[:, slot].set(v[:, t])
        outs.append(A.decode_attention(q[:, t:t + 1], kc, vc,
                                       cache_len=t + 1, ring=True))
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_mla_absorbed_decode_matches_seq():
    """Absorbed-MLA decode == naive expanded MLA at the same position."""
    from repro.configs.base import MLAConfig
    from repro.models import mla as M
    cfg = MLAConfig(kv_lora_rank=16, q_lora_rank=24, rope_head_dim=8,
                    nope_head_dim=16, v_head_dim=16)
    key = jax.random.PRNGKey(2)
    p = M.init_mla(key, 64, 4, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 33, 64))
    pos = jnp.arange(33)
    y_seq, (ckv, kpe) = M.mla_seq(x, p, cfg, 4, pos, 1e4, 1e-5, impl="dense")
    y_dec = M.mla_decode(x[:, 32:33], p, cfg, 4, 32, 1e4, 1e-5, ckv, kpe)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_seq[:, 32]), atol=2e-4, rtol=1e-3)
