"""Parity tests for the vmapped federated cohort engine: the fused round
step (vmap over clients x scan over local steps + stacked aggregation +
broadcast) must reproduce the legacy per-client loop, and the stacked
aggregation operators must match the list-based API bit-for-bit on float32
inputs — including the all-clients-in-outage round (weights sum to zero →
global kept)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import trees
from repro.core.aggregation import (fedavg, fedavg_stacked, masked_fedavg,
                                    masked_fedavg_stacked, partial_fedavg,
                                    partial_fedavg_stacked)


def _tree(seed):
    r = np.random.RandomState(seed)
    return {"x": {"w": jnp.asarray(r.randn(3, 4), jnp.float32)},
            "y": jnp.asarray(r.randn(5), jnp.float32),
            "s": jnp.asarray(r.randn(), jnp.float32)}


def _mask(seed):
    r = np.random.RandomState(seed)
    return {"x": {"w": jnp.asarray(r.randint(0, 2, (3, 4)), jnp.float32)},
            "y": jnp.asarray(r.randint(0, 2, (5,)), jnp.float32),
            "s": jnp.ones((), jnp.float32)}


def _assert_trees_equal(a, b, exact=True):
    fa, fb = trees.flatten(a), trees.flatten(b)
    assert fa.keys() == fb.keys()
    for k in fa:
        if exact:
            np.testing.assert_array_equal(np.asarray(fa[k]),
                                          np.asarray(fb[k]), err_msg=k)
        else:
            np.testing.assert_allclose(np.asarray(fa[k]), np.asarray(fb[k]),
                                       atol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# trees.stack / unstack
# ---------------------------------------------------------------------------


def test_stack_unstack_roundtrip():
    ts = [_tree(i) for i in range(3)]
    st = trees.stack(ts)
    assert trees.flatten(st)["x/w"].shape == (3, 3, 4)
    for orig, back in zip(ts, trees.unstack(st)):
        _assert_trees_equal(orig, back)


def test_stack_preserves_none_leaves():
    sel = [trees.select(_tree(i), lambda p: p.startswith("x"))
           for i in range(2)]
    st = trees.stack(sel)
    flat = trees.flatten(st)
    assert set(flat) == {"x/w"}
    assert flat["x/w"].shape == (2, 3, 4)


# ---------------------------------------------------------------------------
# stacked aggregation vs legacy list API (bit-for-bit on float32)
# ---------------------------------------------------------------------------


def test_fedavg_stacked_matches_list_bitwise():
    ts = [_tree(i) for i in range(4)]
    _assert_trees_equal(fedavg(ts), fedavg_stacked(trees.stack(ts)))
    w = [0.1, 0.4, 0.2, 0.3]
    _assert_trees_equal(fedavg(ts, w),
                        fedavg_stacked(trees.stack(ts), jnp.asarray(w)))


def test_masked_fedavg_stacked_matches_list_bitwise():
    g, ts = _tree(99), [_tree(i) for i in range(3)]
    ms = [_mask(10 + i) for i in range(3)]
    _assert_trees_equal(masked_fedavg(g, ts, ms),
                        masked_fedavg_stacked(g, trees.stack(ts),
                                              trees.stack(ms)))


def test_masked_fedavg_outage_vector_matches_alive_subset():
    """Zero-weight (outage) clients must drop out exactly as if they had
    been Python-filtered from the client list."""
    g, ts = _tree(99), [_tree(i) for i in range(4)]
    ms = [_mask(10 + i) for i in range(4)]
    legacy = masked_fedavg(g, [ts[0], ts[2]], [ms[0], ms[2]])
    stacked = masked_fedavg_stacked(g, trees.stack(ts), trees.stack(ms),
                                    weights=jnp.asarray([1., 0., 1., 0.]))
    _assert_trees_equal(legacy, stacked)


def test_masked_fedavg_all_outage_keeps_global():
    g, ts = _tree(99), [_tree(i) for i in range(3)]
    ms = [_mask(10 + i) for i in range(3)]
    out = masked_fedavg_stacked(g, trees.stack(ts), trees.stack(ms),
                                weights=jnp.zeros(3))
    _assert_trees_equal(out, g)


def test_masked_fedavg_zero_mask_keeps_global():
    g, ts = _tree(99), [_tree(i) for i in range(2)]
    zeros = [jax.tree_util.tree_map(jnp.zeros_like, m)
             for m in [_mask(0), _mask(1)]]
    out = masked_fedavg_stacked(g, trees.stack(ts), trees.stack(zeros))
    _assert_trees_equal(out, g)


def test_partial_fedavg_stacked_matches_list_bitwise():
    g, ts = _tree(99), [_tree(i) for i in range(3)]
    pred = lambda p: p.startswith("x")
    _assert_trees_equal(partial_fedavg(g, ts, pred),
                        partial_fedavg_stacked(g, trees.stack(ts), pred))


# ---------------------------------------------------------------------------
# fused supervised round step semantics (direct engine unit test)
# ---------------------------------------------------------------------------


def _toy_round_step():
    from repro.core.cohort import build_supervised_round
    from repro.optim import sgd
    opt = sgd(0.25)

    def local_step(tr, op, batch):
        loss, g = jax.value_and_grad(
            lambda t: jnp.sum((t["shared"]["w"] - batch["tgt"]) ** 2)
            + jnp.sum((t["local"]["v"] - batch["tgt"]) ** 2))(tr)
        upd, op = opt.update(g, op, tr)
        return trees.tree_add(tr, upd), op, loss

    tr = {"shared": {"w": jnp.zeros(2)}, "local": {"v": jnp.zeros(2)}}
    st_tr = trees.stack([tr, tr])
    st_op = trees.stack([opt.init(tr), opt.init(tr)])
    batches = {"tgt": jnp.asarray([[[1.0, 1.0]] * 3, [[3.0, 3.0]] * 3])}
    step = build_supervised_round(local_step,
                                  lambda p: p.startswith("shared"),
                                  donate=False)
    return step, st_tr, st_op, batches


def test_supervised_round_aggregates_shared_keeps_local():
    step, st_tr, st_op, batches = _toy_round_step()
    out, _, losses = step(st_tr, st_op, batches, jnp.asarray([1.0, 1.0]))
    w = np.asarray(trees.flatten(out)["shared/w"])
    v = np.asarray(trees.flatten(out)["local/v"])
    np.testing.assert_allclose(w[0], w[1])          # shared: broadcast agg
    assert not np.allclose(v[0], v[1])              # local: personalized
    assert losses.shape == (2, 3)
    assert float(losses[0, 0]) > float(losses[0, -1])  # scan actually trains


def test_supervised_round_all_outage_keeps_local():
    step, st_tr, st_op, batches = _toy_round_step()
    out, _, _ = step(st_tr, st_op, batches, jnp.zeros(2))
    w = np.asarray(trees.flatten(out)["shared/w"])
    assert not np.allclose(w[0], w[1])              # no agg, no broadcast


# ---------------------------------------------------------------------------
# engine vs legacy loop, end-to-end (per-round metrics parity)
# ---------------------------------------------------------------------------


def test_pftt_engine_matches_loop():
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(n_clients=2, rounds=3, local_steps=3, pretrain_steps=20,
              samples_per_client=200, seed=0)
    legacy = run_pftt(PFTTConfig(engine=False, **kw))
    fused = run_pftt(PFTTConfig(engine=True, **kw))
    np.testing.assert_allclose(legacy["acc_per_round"],
                               fused["acc_per_round"], atol=1e-5)
    assert legacy["mean_round_bytes"] == fused["mean_round_bytes"]
    assert legacy["mean_round_delay_s"] == fused["mean_round_delay_s"]


def test_pfit_engine_matches_loop():
    from repro.core.pfit import PFITConfig, run_pfit
    kw = dict(n_clients=2, rounds=2, rollout_batch=4, pretrain_steps=15,
              rm_steps=15, d_model=48, n_layers=2, gen_len=8, prompt_len=6,
              seed=0)
    legacy = run_pfit(PFITConfig(engine=False, **kw))
    fused = run_pfit(PFITConfig(engine=True, **kw))
    np.testing.assert_allclose(legacy["reward_per_round"],
                               fused["reward_per_round"], atol=1e-3)
    assert legacy["mean_round_bytes"] == fused["mean_round_bytes"]


def test_pfit_shepherd_engine_matches_loop():
    from repro.core.pfit import PFITConfig, run_pfit
    kw = dict(method="shepherd", n_clients=2, rounds=2, shepherd_steps=2,
              rollout_batch=4, pretrain_steps=15, rm_steps=15, d_model=48,
              n_layers=2, gen_len=8, prompt_len=6, seed=0)
    legacy = run_pfit(PFITConfig(engine=False, **kw))
    fused = run_pfit(PFITConfig(engine=True, **kw))
    np.testing.assert_allclose(legacy["reward_per_round"],
                               fused["reward_per_round"], atol=1e-3)
    assert legacy["mean_round_bytes"] == fused["mean_round_bytes"]
