"""Straggler-tolerant runtime: fault injection, bounded-staleness
aggregation, retransmission, and checkpoint/resume.

Parity discipline mirrors tests/test_cohort_engine.py: the legacy
per-client loop is the oracle, and the fused robust engine must reproduce
its per-round metrics and ledger totals exactly under identical
``FaultPlan`` seeds.  The zero-fault plan must additionally be *bitwise*
the synchronous engine (same accs, same bytes) — the robust machinery is
free when nothing fails.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.robust import RoundPlan, StalenessConfig, StalenessTracker
from repro.wireless.faults import FaultPlan, FaultTrace, RoundFaults


# ---------------------------------------------------------------------------
# FaultPlan / FaultTrace
# ---------------------------------------------------------------------------

FULL_PLAN = dict(dropout_p=0.2, straggle_p=0.25, max_straggle=2,
                 crash_p=0.1, max_crash=3, snr_dip_p=0.2, seed=7)


def test_fault_plan_seeded_and_deterministic():
    a = FaultPlan(**FULL_PLAN).realize(6, 12)
    b = FaultPlan(**FULL_PLAN).realize(6, 12)
    for f in ("train", "tx", "recv", "rejoin", "gain_scale"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    c = FaultPlan(**{**FULL_PLAN, "seed": 8}).realize(6, 12)
    assert not np.array_equal(a.train, c.train)


def test_fault_plan_prefix_stable_across_horizons():
    """A shorter-horizon realization must be a prefix of a longer one —
    what lets --resume replay the same trace for fewer remaining rounds."""
    long = FaultPlan(**FULL_PLAN).realize(5, 10)
    short = FaultPlan(**FULL_PLAN).realize(5, 4)
    for f in ("train", "tx", "recv", "rejoin", "gain_scale"):
        np.testing.assert_array_equal(getattr(long, f)[:4], getattr(short, f))


def test_fault_plan_zero_is_all_ones():
    plan = FaultPlan()
    assert plan.is_zero()
    tr = plan.realize(4, 6)
    assert tr.train.all() and tr.tx.all() and tr.recv.all()
    assert not tr.rejoin.any()
    np.testing.assert_array_equal(tr.gain_scale, 1.0)


def test_fault_trace_clamps_past_horizon():
    tr = FaultPlan(dropout_p=1.0).realize(3, 2)
    rf = tr.round(5)                      # past horizon → fault-free
    assert rf.train.all() and rf.tx.all() and rf.recv.all()
    assert not tr.round(1).train.any()    # in-horizon: everyone dropped


def test_fault_trace_mask_invariants():
    tr = FaultPlan(**FULL_PLAN).realize(8, 30)
    for f in ("train", "tx", "recv", "rejoin"):
        v = getattr(tr, f)
        assert set(np.unique(v)) <= {0.0, 1.0}, f
    # a rejoin round receives the broadcast (resync from global)
    assert (tr.recv[tr.rejoin > 0] == 1.0).all()
    # straggle delivery rounds exist: tx=1 with train=0 somewhere
    assert ((tr.tx > 0) & (tr.train == 0)).any()


def test_fault_plan_serialization_roundtrip(tmp_path):
    plan = FaultPlan(**FULL_PLAN)
    assert FaultPlan.from_dict(plan.to_dict()) == plan
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(plan.to_dict()))
    assert FaultPlan.from_spec(str(p)) == plan
    inline = FaultPlan.from_spec("dropout_p=0.3,max_straggle=4,seed=2")
    assert inline == FaultPlan(dropout_p=0.3, max_straggle=4, seed=2)
    assert FaultPlan.from_spec(None) is None
    assert FaultPlan.from_spec("none") is None
    with pytest.raises(ValueError):
        FaultPlan.from_dict({"dropout": 0.5})     # typo'd field


# ---------------------------------------------------------------------------
# StalenessTracker (host-side bookkeeping both execution paths share)
# ---------------------------------------------------------------------------


def _faults(train, tx, recv=None, rejoin=None):
    n = len(train)
    f32 = lambda v: np.asarray(v, np.float32)
    return RoundFaults(
        train=f32(train), tx=f32(tx),
        recv=f32(recv if recv is not None else [1.0] * n),
        rejoin=f32(rejoin if rejoin is not None else [0.0] * n),
        gain_scale=np.ones(n, np.float32))


def test_tracker_zero_faults_equals_outage_weights():
    tk = StalenessTracker(3, StalenessConfig(a=0.5, max_staleness=2))
    for outage in ([1.0, 1.0, 1.0], [1.0, 0.0, 1.0]):
        plan = tk.begin_round(_faults([1, 1, 1], [1, 1, 1]),
                              np.asarray(outage))
        np.testing.assert_array_equal(plan.agg_w, np.asarray(outage, np.float32))
        np.testing.assert_array_equal(plan.staleness, 0)
        tk.end_round(plan, np.full(3, 100.0))


def test_tracker_retransmits_with_staleness_discount():
    cfg = StalenessConfig(a=1.0, max_staleness=2)
    tk = StalenessTracker(2, cfg)
    # round 0: both train; client 0's uplink is lost to an outage
    p0 = tk.begin_round(_faults([1, 1], [1, 1]), np.asarray([0.0, 1.0]))
    charged = tk.end_round(p0, np.asarray([64.0, 64.0]))
    np.testing.assert_array_equal(charged, [64.0, 64.0])   # both attempted
    # round 1: client 0 straggles (no fresh train) but retransmits the
    # buffered round-0 payload at staleness 1 and the stored bit size
    p1 = tk.begin_round(_faults([0, 1], [1, 1]), np.asarray([1.0, 1.0]))
    assert p1.attempt[0] == 1.0 and p1.staleness[0] == 1
    np.testing.assert_allclose(p1.agg_w, [cfg.discount(np.asarray([1]))[0], 1.0])
    charged = tk.end_round(p1, np.asarray([0.0, 64.0]))
    assert charged[0] == 64.0                              # stored bits
    # delivered → the pending slot is free; nothing more on the air
    p2 = tk.begin_round(_faults([0, 1], [1, 1]), np.asarray([1.0, 1.0]))
    assert p2.attempt[0] == 0.0 and p2.agg_w[0] == 0.0


def test_tracker_max_staleness_zero_drops_like_sync():
    tk = StalenessTracker(1, StalenessConfig(max_staleness=0))
    p0 = tk.begin_round(_faults([1], [1]), np.asarray([0.0]))   # outage
    tk.end_round(p0, np.asarray([32.0]))
    p1 = tk.begin_round(_faults([0], [1]), np.asarray([1.0]))
    assert p1.attempt[0] == 0.0        # aged past the bound → abandoned


def test_tracker_rejoin_clears_pending():
    tk = StalenessTracker(1, StalenessConfig(max_staleness=5))
    p0 = tk.begin_round(_faults([1], [1]), np.asarray([0.0]))
    tk.end_round(p0, np.asarray([32.0]))
    p1 = tk.begin_round(_faults([0], [0], rejoin=[1.0]), np.asarray([1.0]))
    tk.end_round(p1, np.asarray([0.0]))
    p2 = tk.begin_round(_faults([0], [1]), np.asarray([1.0]))
    assert p2.attempt[0] == 0.0        # crash dropped the buffered payload


def test_tracker_state_dict_roundtrip():
    tk = StalenessTracker(2, StalenessConfig(a=0.5, max_staleness=3))
    p = tk.begin_round(_faults([1, 1], [1, 1]), np.asarray([0.0, 1.0]))
    tk.end_round(p, np.asarray([10.0, 20.0]))
    tk2 = StalenessTracker(2, tk.cfg)
    tk2.load_state_dict(json.loads(json.dumps(tk.state_dict())))
    np.testing.assert_array_equal(tk.valid, tk2.valid)
    np.testing.assert_array_equal(tk.age, tk2.age)
    np.testing.assert_array_equal(tk.bits, tk2.bits)


# ---------------------------------------------------------------------------
# robust engine round step: direct unit semantics (ghost padding)
# ---------------------------------------------------------------------------


def _toy_robust_setup(n_clients):
    from repro import trees
    from repro.optim import sgd

    def loss_fn(tr, batch):
        return jnp.mean((tr["shared"]["w"].sum() + tr["local"]["v"].sum()
                         - batch["tgt"]) ** 2)

    opt = sgd(1e-2)

    def local_step(tr, op, batch):
        loss, grads = jax.value_and_grad(loss_fn)(tr, batch)
        updates, op = opt.update(grads, op, tr)
        return jax.tree_util.tree_map(lambda p, u: p + u, tr, updates), op, loss

    rng = np.random.RandomState(0)
    mk = lambda i: {"shared": {"w": jnp.asarray(rng.randn(3), jnp.float32)},
                    "local": {"v": jnp.asarray(rng.randn(2), jnp.float32)}}
    ts = [mk(i) for i in range(n_clients)]
    st_tr = trees.stack(ts)
    st_op = trees.stack([opt.init(t) for t in ts])
    batches = {"tgt": jnp.asarray(rng.randn(n_clients, 4, 1), jnp.float32)}
    return local_step, st_tr, st_op, batches


def test_robust_round_ghost_padding_invariant():
    """Ghost clients (copies of client 0, fault masks padded train/recv=1,
    rejoin=0, agg weight 0 — ``CohortSharding.pad_vec`` semantics) must
    leave the real clients' robust round output bitwise unchanged."""
    from repro import trees
    from repro.core.cohort import build_supervised_round

    local_step, st_tr2, st_op2, batches2 = _toy_robust_setup(2)
    step = build_supervised_round(local_step,
                                  lambda p: p.startswith("shared"),
                                  donate=False, robust=True)
    pending2 = jax.tree_util.tree_map(
        jnp.zeros_like, trees.select(st_tr2, lambda p: p.startswith("shared")))

    pad = lambda t: jax.tree_util.tree_map(
        lambda l: jnp.concatenate([l, l[:1], l[:1]]), t)
    st_tr4, st_op4, batches4, pending4 = (pad(st_tr2), pad(st_op2),
                                          pad(batches2), pad(pending2))
    # client 1 straggles: no train, retransmits pending at half weight
    train2 = jnp.asarray([1.0, 0.0])
    aggw2 = jnp.asarray([1.0, 0.5])
    recv2 = jnp.asarray([1.0, 1.0])
    rej2 = jnp.asarray([0.0, 0.0])
    one, zero = jnp.ones(2), jnp.zeros(2)
    ref = step(st_tr2, st_op2, pending2, batches2, train2, aggw2, recv2, rej2,
               one)
    got = step(st_tr4, st_op4, pending4, batches4,
               jnp.concatenate([train2, one]),      # ghosts train like sync
               jnp.concatenate([aggw2, zero]),      # ...at zero agg weight
               jnp.concatenate([recv2, one]),
               jnp.concatenate([rej2, zero]),
               jnp.ones(4))                         # all on time
    for r, g in zip(ref[:3], got[:3]):
        for k, leaf in trees.flatten(r).items():
            np.testing.assert_array_equal(
                np.asarray(leaf), np.asarray(trees.flatten(g)[k])[:2],
                err_msg=k)
    np.testing.assert_array_equal(np.asarray(ref[3]), np.asarray(got[3])[:2])


# ---------------------------------------------------------------------------
# engine vs legacy loop under injected faults (end-to-end parity)
# ---------------------------------------------------------------------------

FAULTY = FaultPlan(dropout_p=0.3, straggle_p=0.3, max_straggle=2,
                   crash_p=0.15, max_crash=2, snr_dip_p=0.25, seed=3)
PFTT_KW = dict(n_clients=2, rounds=3, local_steps=3, pretrain_steps=20,
               samples_per_client=200, seed=0)
ROBUST_KW = dict(fault_plan=FAULTY, staleness_a=0.5, max_staleness=2)


def _assert_ledgers_equal(a, b):
    assert a["total_bytes"] == b["total_bytes"]
    np.testing.assert_allclose(a["mean_round_delay_s"],
                               b["mean_round_delay_s"], equal_nan=True)
    assert a["total_energy_j"] == b["total_energy_j"]


def test_pftt_fault_engine_matches_loop():
    from repro.core.pftt import PFTTConfig, run_pftt
    legacy = run_pftt(PFTTConfig(engine=False, **PFTT_KW, **ROBUST_KW))
    fused = run_pftt(PFTTConfig(engine=True, **PFTT_KW, **ROBUST_KW))
    np.testing.assert_allclose(legacy["acc_per_round"],
                               fused["acc_per_round"], atol=1e-5)
    _assert_ledgers_equal(legacy, fused)


def test_pftt_zero_fault_plan_is_bitwise_sync():
    """FaultPlan() + staleness discounting off must be byte-for-byte the
    synchronous engine — accs, bytes, delay, energy."""
    from repro.core.pftt import PFTTConfig, run_pftt
    sync = run_pftt(PFTTConfig(engine=True, **PFTT_KW))
    robust = run_pftt(PFTTConfig(engine=True, **PFTT_KW,
                                 fault_plan=FaultPlan(), max_staleness=2))
    assert sync["acc_per_round"] == robust["acc_per_round"]   # exact
    _assert_ledgers_equal(sync, robust)


def test_pftt_all_outage_degrades_gracefully():
    """Forced all-outage rounds (deep SNR) must no-op the global update
    without poisoning state, identically in both execution paths."""
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = {**PFTT_KW, "snr_db": -30.0}
    legacy = run_pftt(PFTTConfig(engine=False, **kw, **ROBUST_KW))
    fused = run_pftt(PFTTConfig(engine=True, **kw, **ROBUST_KW))
    assert np.isfinite(fused["acc_per_round"]).all()
    np.testing.assert_allclose(legacy["acc_per_round"],
                               fused["acc_per_round"], atol=1e-5)
    _assert_ledgers_equal(legacy, fused)


def test_pftt_fault_sharded_one_device_matches_unsharded():
    """The robust round under shard_map (1-device mesh) must reproduce the
    unsharded engine — fault masks and agg weights ride the client axis."""
    from repro.core.pftt import PFTTConfig, run_pftt
    plain = run_pftt(PFTTConfig(engine=True, **PFTT_KW, **ROBUST_KW))
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    sharded = run_pftt(PFTTConfig(engine=True, **PFTT_KW, **ROBUST_KW),
                       mesh=mesh, client_axes=("pod", "data"))
    np.testing.assert_allclose(plain["acc_per_round"],
                               sharded["acc_per_round"], atol=1e-5)
    _assert_ledgers_equal(plain, sharded)


PFIT_KW = dict(n_clients=2, rounds=2, rollout_batch=4, pretrain_steps=15,
               rm_steps=15, d_model=48, n_layers=2, gen_len=8, prompt_len=6,
               seed=0)


def test_pfit_ppo_fault_engine_matches_loop():
    from repro.core.pfit import PFITConfig, run_pfit
    legacy = run_pfit(PFITConfig(engine=False, **PFIT_KW, **ROBUST_KW))
    fused = run_pfit(PFITConfig(engine=True, **PFIT_KW, **ROBUST_KW))
    np.testing.assert_allclose(legacy["reward_per_round"],
                               fused["reward_per_round"], atol=1e-3)
    _assert_ledgers_equal(legacy, fused)


def test_pfit_shepherd_fault_engine_matches_loop():
    from repro.core.pfit import PFITConfig, run_pfit
    kw = dict(method="shepherd", shepherd_steps=2, **PFIT_KW)
    legacy = run_pfit(PFITConfig(engine=False, **kw, **ROBUST_KW))
    fused = run_pfit(PFITConfig(engine=True, **kw, **ROBUST_KW))
    np.testing.assert_allclose(legacy["reward_per_round"],
                               fused["reward_per_round"], atol=1e-3)
    _assert_ledgers_equal(legacy, fused)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


def test_pftt_kill_and_resume_reproduces_uninterrupted_run(tmp_path):
    """Kill after 2 of 4 rounds, resume from the round checkpoints: the
    continued run must reproduce the uninterrupted run's per-round metrics
    and ledger exactly."""
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = {**PFTT_KW, "rounds": 4}
    full = run_pftt(PFTTConfig(engine=True, **kw, **ROBUST_KW))
    ck = str(tmp_path / "ck")
    os.makedirs(ck)
    run_pftt(PFTTConfig(engine=True, **{**kw, "rounds": 2}, **ROBUST_KW,
                        ckpt_dir=ck))                       # "killed" here
    resumed = run_pftt(PFTTConfig(engine=True, **kw, **ROBUST_KW,
                                  ckpt_dir=ck, resume=True))
    assert resumed["acc_per_round"] == full["acc_per_round"]
    _assert_ledgers_equal(full, resumed)
