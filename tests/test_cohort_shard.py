"""Sharded cohort engine tests.

Fast tier-1 part: the ``shard_map``/psum round on a 1-device ("pod","data")
mesh must match the unsharded engine (the collective math collapses to the
single-device math), ghost clients (zero aggregation weight) must be
invariant for the real clients, and the psum'd masked aggregation must
reproduce the plain stacked operator including all-outage keep-global.

Multi-device part (marked ``multidevice``/``slow``, subprocess so the
forced host-device-count flag doesn't leak): one fused PFTT and PFIT round
under ``shard_map`` spanning 8 host-platform devices, parity against the
single-device engine — including non-divisible (ghost-padded) cohorts and
forced all-outage rounds."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import trees
from repro.core.aggregation import masked_fedavg_stacked
from repro.core.cohort import build_supervised_round
from repro.optim import sgd
from repro.sharding import (CohortSharding, client_shard_axes,
                            cohort_sharding, shard_map)


# ---------------------------------------------------------------------------
# cohort sharding policy (pure math)
# ---------------------------------------------------------------------------


def _mesh11():
    return jax.make_mesh((1, 1), ("pod", "data"))


def test_client_shard_axes_excludes_model():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert client_shard_axes(mesh) == ("data",)
    assert client_shard_axes(_mesh11()) == ("pod", "data")
    assert client_shard_axes(_mesh11(), ("data",)) == ("data",)


def test_cohort_sharding_ghost_padding_math():
    cs = cohort_sharding(_mesh11(), 3)
    assert (cs.n_shards, cs.total, cs.n_pad) == (1, 3, 0)
    # fake a 4-shard layout to exercise the padding arithmetic
    cs4 = CohortSharding(mesh=_mesh11(), axes=("pod", "data"), n_clients=3,
                         total=4)
    assert cs4.n_pad == 1
    assert cs4.pad([10, 11, 12]) == [10, 11, 12, 10]
    np.testing.assert_array_equal(cs4.pad_weights([1.0, 0.5, 2.0]),
                                  [1.0, 0.5, 2.0, 0.0])


# ---------------------------------------------------------------------------
# sharded round on a 1-device mesh == unsharded engine
# ---------------------------------------------------------------------------


def _toy_round(mesh=None, n_clients=2):
    opt = sgd(0.25)

    def local_step(tr, op, batch):
        loss, g = jax.value_and_grad(
            lambda t: jnp.sum((t["shared"]["w"] - batch["tgt"]) ** 2)
            + jnp.sum((t["local"]["v"] - batch["tgt"]) ** 2))(tr)
        upd, op = opt.update(g, op, tr)
        return trees.tree_add(tr, upd), op, loss

    tr = {"shared": {"w": jnp.zeros(2)}, "local": {"v": jnp.zeros(2)}}
    st_tr = trees.stack([tr] * n_clients)
    st_op = trees.stack([opt.init(tr)] * n_clients)
    tgts = np.stack([np.full((3, 2), 1.0 + 2.0 * ci, np.float32)
                     for ci in range(n_clients)])
    batches = {"tgt": jnp.asarray(tgts)}
    step = build_supervised_round(local_step,
                                  lambda p: p.startswith("shared"),
                                  donate=False, mesh=mesh)
    return step, st_tr, st_op, batches


def test_sharded_round_one_device_mesh_matches_unsharded():
    plain, st_tr, st_op, batches = _toy_round(mesh=None)
    sharded, *_ = _toy_round(mesh=_mesh11())
    w = jnp.asarray([1.0, 0.0])        # client 1 in outage
    ref = plain(st_tr, st_op, batches, w)
    got = sharded(st_tr, st_op, batches, w)
    for r, g in zip(trees.flatten(ref).values(), trees.flatten(got).values()):
        np.testing.assert_allclose(np.asarray(r), np.asarray(g), atol=1e-6)


def test_sharded_round_all_outage_keeps_local():
    sharded, st_tr, st_op, batches = _toy_round(mesh=_mesh11())
    out, _, _ = sharded(st_tr, st_op, batches, jnp.zeros(2))
    w = np.asarray(trees.flatten(out)["shared/w"])
    assert not np.allclose(w[0], w[1])     # gate: no agg, no broadcast


def test_ghost_clients_do_not_change_real_clients():
    """Zero-weight ghost padding (copies of client 0) must leave the real
    clients' round output bitwise unchanged — the invariant the sharded
    engine's non-divisible-cohort padding relies on."""
    step2, st_tr2, st_op2, batches2 = _toy_round(n_clients=2)
    step4, st_tr4, st_op4, _ = _toy_round(n_clients=4)
    # ghosts = copies of client 0, zero weight
    pad = lambda t: jax.tree_util.tree_map(
        lambda l: jnp.concatenate([l, l[:1], l[:1]]), t)
    batches4 = pad(batches2)
    st_tr4 = pad(st_tr2)
    st_op4 = pad(st_op2)
    w2, w4 = jnp.asarray([1.0, 1.0]), jnp.asarray([1.0, 1.0, 0.0, 0.0])
    ref, _, losses2 = step2(st_tr2, st_op2, batches2, w2)
    got, _, losses4 = step4(st_tr4, st_op4, batches4, w4)
    for k, r in trees.flatten(ref).items():
        np.testing.assert_array_equal(np.asarray(r),
                                      np.asarray(trees.flatten(got)[k])[:2],
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(losses2),
                                  np.asarray(losses4)[:2])


def test_masked_fedavg_axis_names_matches_plain():
    """The psum'd masked aggregation under shard_map (1-device mesh) must
    reproduce the plain stacked operator — including all-outage (den 0
    everywhere → global kept)."""
    r = np.random.RandomState(0)
    g = {"w": jnp.asarray(r.randn(3, 4), jnp.float32)}
    st = {"w": jnp.asarray(r.randn(5, 3, 4), jnp.float32)}
    ms = {"w": jnp.asarray(r.randint(0, 2, (5, 3, 4)), jnp.float32)}
    mesh = _mesh11()
    axes = ("pod", "data")

    def agg(g, t, m, w):
        return masked_fedavg_stacked(g, t, m, w, axis_names=axes)

    f = shard_map(agg, mesh=mesh,
                  in_specs=(P(), P(axes), P(axes), P(axes)),
                  out_specs=P(), check_vma=False)
    for w in ([1.0, 0.0, 1.0, 0.5, 0.0], [0.0] * 5):
        wv = jnp.asarray(w)
        ref = masked_fedavg_stacked(g, st, ms, wv)
        got = f(g, st, ms, wv)
        np.testing.assert_allclose(np.asarray(ref["w"]),
                                   np.asarray(got["w"]), atol=1e-6)


# ---------------------------------------------------------------------------
# 8-device SPMD execution (subprocess; marked multidevice + slow)
# ---------------------------------------------------------------------------

_PREAMBLE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    assert len(jax.devices()) == 8
""")

PFTT_SUBPROC = _PREAMBLE + textwrap.dedent("""
    from repro.core.pftt import PFTTConfig, run_pftt

    # the fused round really spans all 8 devices
    from repro import trees
    from repro.core.cohort import build_supervised_round
    from repro.optim import sgd
    from repro.sharding import cohort_sharding
    opt = sgd(0.1)
    def local_step(tr, op, b):
        loss, grad = jax.value_and_grad(
            lambda t: jnp.sum((t["w"] - b["tgt"]) ** 2))(tr)
        upd, op = opt.update(grad, op, tr)
        return trees.tree_add(tr, upd), op, loss
    cs = cohort_sharding(mesh, 8)
    tr = {"w": jnp.zeros(2)}
    st_tr = jax.device_put(trees.stack([tr] * 8), cs.named)
    st_op = jax.device_put(trees.stack([opt.init(tr)] * 8), cs.named)
    bt = jax.device_put({"tgt": jnp.ones((8, 3, 2))}, cs.named)
    w = jax.device_put(jnp.ones(8), cs.named)
    step = build_supervised_round(local_step, donate=False, mesh=mesh)
    out, _, _ = step(st_tr, st_op, bt, w)
    assert len(out["w"].sharding.device_set) == 8, out["w"].sharding
    print("SPAN8_OK")

    # engine parity: sharded vs single-device, divisible cohort (8 over 8)
    kw = dict(rounds=2, local_steps=2, pretrain_steps=5,
              samples_per_client=120, d_model=32, seed=0)
    for n, tag in ((8, "DIV"), (3, "GHOST")):
        base = run_pftt(PFTTConfig(n_clients=n, **kw))
        shard = run_pftt(PFTTConfig(n_clients=n, **kw), mesh=mesh)
        np.testing.assert_allclose(base["acc_per_round"],
                                   shard["acc_per_round"], atol=1e-6)
        assert base["mean_round_bytes"] == shard["mean_round_bytes"]
        print(tag + "_OK", base["acc_per_round"])

    # forced all-outage rounds (snr -> -inf): gate parity
    kw_out = dict(kw, snr_db=-30.0)
    base = run_pftt(PFTTConfig(n_clients=3, **kw_out))
    shard = run_pftt(PFTTConfig(n_clients=3, **kw_out), mesh=mesh)
    np.testing.assert_allclose(base["acc_per_round"],
                               shard["acc_per_round"], atol=1e-6)
    print("OUTAGE_OK")
""")

_PFIT_KW = textwrap.dedent("""
    from repro.core.pfit import PFITConfig, run_pfit
    kw = dict(n_clients=2, rounds=2, rollout_batch=4, pretrain_steps=10,
              rm_steps=10, d_model=48, n_layers=2, gen_len=8, prompt_len=6,
              seed=0)
""")

PFIT_PPO_SUBPROC = _PREAMBLE + _PFIT_KW + textwrap.dedent("""
    base = run_pfit(PFITConfig(**kw))
    shard = run_pfit(PFITConfig(**kw), mesh=mesh)
    np.testing.assert_allclose(base["reward_per_round"],
                               shard["reward_per_round"], atol=1e-3)
    assert base["mean_round_bytes"] == shard["mean_round_bytes"]
    print("PPO_OK", base["reward_per_round"])
""")

PFIT_SHEPHERD_SUBPROC = _PREAMBLE + _PFIT_KW + textwrap.dedent("""
    kw2 = dict(kw, method="shepherd", shepherd_steps=2)
    base = run_pfit(PFITConfig(**kw2))
    shard = run_pfit(PFITConfig(**kw2), mesh=mesh)
    np.testing.assert_allclose(base["reward_per_round"],
                               shard["reward_per_round"], atol=1e-3)
    print("SHEPHERD_OK", base["reward_per_round"])
""")


def _run_subproc(code: str, timeout: int = 1800):
    # generous timeout: 8 forced host-platform devices multiply compile
    # time, and CI/sandbox hosts are often oversubscribed.  Inherit the
    # environment (HOME/PATH differ across CI runners); the subprocess sets
    # its own XLA_FLAGS before importing jax.
    import os
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout,
                          env={**os.environ, "PYTHONPATH": "src"})


@pytest.mark.multidevice
@pytest.mark.slow
def test_pftt_sharded_8dev_matches_single_device():
    proc = _run_subproc(PFTT_SUBPROC)
    for marker in ("SPAN8_OK", "DIV_OK", "GHOST_OK", "OUTAGE_OK"):
        assert marker in proc.stdout, (marker, proc.stdout,
                                       proc.stderr[-3000:])


@pytest.mark.multidevice
@pytest.mark.slow
def test_pfit_ppo_sharded_8dev_matches_single_device():
    proc = _run_subproc(PFIT_PPO_SUBPROC)
    assert "PPO_OK" in proc.stdout, (proc.stdout, proc.stderr[-3000:])


@pytest.mark.multidevice
@pytest.mark.slow
def test_pfit_shepherd_sharded_8dev_matches_single_device():
    proc = _run_subproc(PFIT_SHEPHERD_SUBPROC)
    assert "SHEPHERD_OK" in proc.stdout, (proc.stdout, proc.stderr[-3000:])
