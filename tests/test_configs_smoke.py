"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED same-family variant
(≤2 pattern repeats, d_model ≤ 512, ≤4 experts) and runs one forward +
train-grad step and one prefill+decode step on CPU, asserting shapes and
no NaNs.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_OWN, get_config
from repro.models import Model
from repro.sharding import MeshCtx

MESH = MeshCtx.single_device()


def _inputs(cfg, key, b=2, s=64):
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
    if cfg.n_prefix_tokens:
        kw["patches"] = jax.random.normal(key, (b, cfg.n_prefix_tokens,
                                                cfg.prefix_dim))
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return tokens, kw


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_OWN)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, meshctx=MESH)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens, kw = _inputs(cfg, key)
    b, s = tokens.shape

    if cfg.is_encoder_only:
        loss, acc = model.cls_loss(params, {"tokens": tokens,
                                            "label": jnp.zeros((b,), jnp.int32)})
        assert np.isfinite(float(loss))
        return

    hidden, aux = model.forward(params, tokens, **kw)
    exp_s = s + (cfg.n_prefix_tokens or 0)
    assert hidden.shape == (b, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any())

    batch = dict(tokens=tokens, labels=tokens, mask=jnp.ones((b, s)), **kw)
    loss, grads = jax.value_and_grad(lambda p: model.lm_loss(p, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_consistency(arch):
    """prefill + one decode step must match the full forward's last logits."""
    cfg = get_config(arch).reduced()
    if cfg.is_encoder_only:
        pytest.skip("encoder-only: no decode step")
    model = Model(cfg, meshctx=MESH)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    tokens, kw = _inputs(cfg, key, s=33)

    hidden, _ = model.forward(params, tokens, **kw)
    want = model.logits(params, hidden[:, -1])
    _, cache = model.prefill(params, tokens[:, :32], cache_len=64, **kw)
    got, cache2 = model.decode_step(params, cache, tokens[:, 32:33])
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               atol=2e-4, rtol=2e-3)
    assert int(cache2["pos"]) == 33 + (cfg.n_prefix_tokens or 0)


def test_long_context_policy():
    """long_500k legality: every assigned arch must either be attention-free
    or expose the block-sparse variant (DESIGN.md §4)."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        assert cfg.attention_free or cfg.sparse_attn is not None, arch


def test_param_counts_match_init():
    """Analytic param_count ≈ actual init leaf count (exact for non-paper
    archs; analytic model is used by comm accounting + roofline)."""
    from repro import trees
    for arch in ("tinyllama-1.1b", "mamba2-1.3b", "dbrx-132b"):
        cfg = get_config(arch).reduced()
        model = Model(cfg, meshctx=MESH)
        params = model.init(jax.random.PRNGKey(0))
        actual = trees.count_params(params)
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.06, (arch, actual, analytic)
