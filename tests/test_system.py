"""End-to-end behaviour tests for the paper's system (deliverable c:
integration).  Short federated runs asserting the paper's qualitative
claims hold: PFTT learns under non-IID data with partial aggregation; PFIT's
PPO improves the personalized reward; the generic FL runner aggregates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="module")
def pftt_result():
    from repro.core.pftt import PFTTConfig, run_pftt
    return run_pftt(PFTTConfig(rounds=6, local_steps=5, pretrain_steps=100,
                               samples_per_client=150, seed=0))


@pytest.mark.slow
def test_pftt_learns(pftt_result):
    accs = pftt_result["acc_per_round"]
    assert accs[-1] > accs[0] + 0.15, accs
    assert accs[-1] > 0.55, accs


@pytest.mark.slow
def test_pftt_comm_is_partial(pftt_result):
    """PFTT uploads only adapters+head — far below full-model bytes."""
    from repro.configs import get_config
    full_bytes = get_config("roberta-base").reduced(
        d_model=128, repeats=2).param_count() * 4
    assert pftt_result["mean_round_bytes"] < 0.2 * full_bytes * 4  # 4 clients


@pytest.mark.slow
def test_vanilla_fl_uploads_more_than_pftt(pftt_result):
    from repro.core.pftt import PFTTConfig, run_pftt
    res_v = run_pftt(PFTTConfig(method="vanilla_fl", rounds=1, local_steps=1,
                                pretrain_steps=5, samples_per_client=80,
                                seed=0))
    assert res_v["mean_round_bytes"] > pftt_result["mean_round_bytes"]


@pytest.mark.slow
def test_pfit_ppo_improves_reward():
    """Isolated PPO against a ground-truth topical reward must improve
    (fast, deterministic version of the Fig. 4 trend)."""
    from repro.configs import get_config
    from repro.core.pfit import _pretrain_policy
    from repro.data.synthetic import InstructionCorpus, topic_tokens
    from repro.models import Model
    from repro.optim import adamw
    from repro.rlhf.ppo import PPOConfig, PPOTrainer
    from repro.rlhf.rollout import generate
    from repro.sharding import MeshCtx

    key = jax.random.PRNGKey(0)
    cfg = get_config("gpt2-small").reduced(d_model=96, repeats=2)
    model = Model(cfg, meshctx=MeshCtx.single_device())
    corpus = InstructionCorpus(seq_len=32, prompt_len=12)
    params = model.init(key)
    params = _pretrain_policy(key, model, params, corpus, 120, 1e-3, 16, False)
    params["value_head"] = jnp.zeros((cfg.d_model, 1), jnp.float32)
    ref = params
    opt = adamw(5e-4)
    opt_state = opt.init(params)
    ppo = PPOTrainer(model, opt, PPOConfig(gen_len=20, kl_coef=0.02), 12)
    gen = jax.jit(lambda p, pr, k: generate(model, p, pr, 20, k))
    tt = np.asarray(topic_tokens(0))
    rng = np.random.RandomState(0)
    fracs = []
    for rnd in range(10):
        s = corpus.sample(24, topic_probs=np.eye(8)[0], rng=rng)
        prompts = jnp.asarray(s["tokens"][:, :12])
        toks = gen(params, prompts, jax.random.fold_in(key, rnd))
        frac = np.isin(np.asarray(toks[:, 12:]), tt).mean(1)
        fracs.append(frac.mean())
        params, opt_state, _ = ppo.round(params, ref, opt_state, toks,
                                         jnp.asarray(frac * 2.0))
    assert np.mean(fracs[-3:]) > np.mean(fracs[:3]) + 0.05, fracs


def test_generic_fl_runner_aggregates():
    """fl.client/server/rounds: clients converge to a shared mean under
    FedAvg of a quadratic objective."""
    from repro import trees
    from repro.fl import FLClient, FLServer, run_rounds
    from repro.optim import sgd

    opt = sgd(0.2)
    targets = [jnp.array([1.0]), jnp.array([3.0])]

    def make_step(tgt):
        def step(trainable, opt_state, batch):
            g = jax.grad(lambda t: jnp.sum((t["w"] - tgt) ** 2))(trainable)
            upd, opt_state = opt.update(g, opt_state, trainable)
            return trees.tree_add(trainable, upd), opt_state, 0.0
        return step

    clients = [FLClient(cid=i, trainable={"w": jnp.zeros(1)},
                        opt_state=opt.init({"w": jnp.zeros(1)}),
                        data_iter=iter(lambda: None, 1),
                        step_fn=make_step(t)) for i, t in enumerate(targets)]
    server = FLServer(channel=None)
    run_rounds(server, clients, rounds=20, local_steps=2)
    w0 = float(clients[0].trainable["w"][0])
    w1 = float(clients[1].trainable["w"][0])
    assert abs(w0 - w1) < 1e-4          # aggregated to common model
    assert abs(w0 - 2.0) < 0.2          # near the mean of targets


@pytest.mark.slow
def test_pfit_short_federated_run():
    """2-round federated PFIT end-to-end (wiring: channel, masks, masked
    aggregation, eval) — smoke-level runtime."""
    from repro.core.pfit import PFITConfig, run_pfit
    res = run_pfit(PFITConfig(rounds=2, n_clients=2, rollout_batch=4,
                              pretrain_steps=30, rm_steps=30, d_model=64,
                              n_layers=2, gen_len=12, prompt_len=8))
    assert len(res["reward_per_round"]) == 2
    assert res["mean_round_bytes"] > 0
    assert np.isfinite(res["final_reward"])
