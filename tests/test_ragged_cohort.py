"""Ragged cohorts compile to ONE fused dispatch — pad-and-mask machinery.

``HostBatchStacker`` pads unequal per-client batch shapes to the per-leaf
max and emits a ``"valid"`` sample mask; the losses weight by it, so padded
rows contribute exactly zero to loss, gradients, and aggregation.  The PFTT
engine therefore never falls back to the legacy per-client loop: parity
with that loop must hold to ≤1e-5 on ragged cohorts, the fused round must
be a single dispatch, and the sharded (ghost-padded, non-divisible) case
must agree across 8 devices."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cohort import HostBatchStacker


# ---------------------------------------------------------------------------
# HostBatchStacker pad-and-mask unit behavior
# ---------------------------------------------------------------------------


def test_stacker_ragged_pads_and_masks():
    stacker = HostBatchStacker()
    batches = [
        [{"x": np.full((3, 2), 1.0, np.float32)}],
        [{"x": np.full((2, 2), 5.0, np.float32)}],
    ]
    out = stacker(batches)
    assert out["x"].shape == (2, 1, 3, 2)        # padded to max batch 3
    v = np.asarray(out["valid"])
    np.testing.assert_array_equal(v, [[[1, 1, 1]], [[1, 1, 0]]])
    x = np.asarray(out["x"])
    np.testing.assert_array_equal(x[1, 0, 2], np.zeros(2))   # pad row defined
    np.testing.assert_array_equal(x[1, 0, :2], np.full((2, 2), 5.0))


def test_stacker_ragged_buffer_reuse_no_stale_rows():
    """The reused buffer must not leak a previous round's rows into the pad
    region: the valid mask is rewritten fully each call and masked rows are
    exactly the non-filled ones."""
    stacker = HostBatchStacker()
    big = [[{"x": np.full((4, 2), 7.0, np.float32)}],
           [{"x": np.full((3, 2), 8.0, np.float32)}]]
    small = [[{"x": np.full((2, 2), 1.0, np.float32)}],
             [{"x": np.full((4, 2), 2.0, np.float32)}]]
    stacker(big)
    buf_id = id(stacker._bufs["x"])
    out = stacker(small)
    assert id(stacker._bufs["x"]) == buf_id      # no realloc
    v = np.asarray(out["valid"])
    np.testing.assert_array_equal(v, [[[1, 1, 0, 0]], [[1, 1, 1, 1]]])
    # stale 7.0 rows may remain in the pad region — the mask excludes them
    x = np.asarray(out["x"])
    np.testing.assert_array_equal(x[0, 0, :2], np.full((2, 2), 1.0))
    assert float((x[0, 0] * v[0, 0, :, None]).sum()) == 4 * 1.0


def test_stacker_uniform_to_ragged_reallocates():
    """A cohort whose shapes drift after the first allocation (uniform →
    ragged, or a new max batch) must pay a realloc, not crash."""
    stacker = HostBatchStacker()
    uni = [[{"x": np.full((4, 2), 7.0, np.float32)}],
           [{"x": np.full((4, 2), 8.0, np.float32)}]]
    out = stacker(uni)
    assert "valid" not in out
    rag = [[{"x": np.full((2, 2), 1.0, np.float32)}],
           [{"x": np.full((5, 2), 2.0, np.float32)}]]
    out = stacker(rag)
    assert out["x"].shape == (2, 1, 5, 2)
    np.testing.assert_array_equal(np.asarray(out["valid"]),
                                  [[[1, 1, 0, 0, 0]], [[1, 1, 1, 1, 1]]])


def test_stacker_uniform_cohort_unchanged():
    """Equal shapes: no "valid" leaf, no padding — bitwise the old layout."""
    stacker = HostBatchStacker()
    batches = [[{"x": np.full((2, 3), 1.0 + ci, np.float32)}
                for _ in range(2)] for ci in range(2)]
    out = stacker(batches)
    assert "valid" not in out
    assert out["x"].shape == (2, 2, 2, 3)


# ---------------------------------------------------------------------------
# PFTT: ragged cohorts run the engine and match the legacy loop
# ---------------------------------------------------------------------------


def _pftt_kw(**over):
    # samples_per_client chosen so the Dirichlet split leaves clients with
    # unequal train counts < batch → ragged per-client batch sizes
    kw = dict(n_clients=3, rounds=2, local_steps=2, pretrain_steps=10,
              samples_per_client=30, batch=16, d_model=32, seed=0)
    kw.update(over)
    return kw


def test_pftt_ragged_cohort_engine_matches_legacy_loop():
    from repro.core.pftt import PFTTConfig, run_pftt
    eng = run_pftt(PFTTConfig(engine=True, **_pftt_kw()))
    assert eng["ragged_cohort"], "workload no longer ragged — retune sizes"
    assert eng["fused_engine"]
    leg = run_pftt(PFTTConfig(engine=False, **_pftt_kw()))
    np.testing.assert_allclose(eng["acc_per_round"], leg["acc_per_round"],
                               atol=1e-5)
    assert eng["mean_round_bytes"] == leg["mean_round_bytes"]
    # eval side: whole ragged cohort scored in one fused dispatch per round
    assert eng["eval_dispatches_per_round"] == 1


def test_arch_round_ragged_single_dispatch():
    """The generic arch round (ragged by construction) is one dispatch per
    round with exact oracle parity — raggedness never re-triggers the
    legacy loop."""
    from repro.core.arch_round import ArchRoundConfig, run_arch_round
    res = run_arch_round(ArchRoundConfig(
        arch="gpt2-small", n_clients=3, rounds=2, local_steps=1, batch=3,
        seq_len=12, d_model=32, oracle=True))
    assert res["ragged"]
    assert res["dispatches_per_round"] == 1.0
    assert res["dense_merges_in_engine"] == 0
    assert res["oracle_loss_max_err"] <= 1e-5


# ---------------------------------------------------------------------------
# sharded ragged cohort: ghost-padded non-divisible case over 8 devices
# ---------------------------------------------------------------------------

RAGGED_SHARD_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core.arch_round import ArchRoundConfig, run_arch_round
    # 3 ragged clients over 8 shards → 5 zero-weight ghosts
    cfg = ArchRoundConfig(arch="gpt2-small", n_clients=3, rounds=2,
                          local_steps=1, batch=3, seq_len=12, d_model=32,
                          oracle=True)
    shard = run_arch_round(cfg, mesh=mesh, client_axes=("data",))
    assert shard["n_ghosts"] == 5, shard["n_ghosts"]
    assert shard["ragged"]
    assert shard["dispatches_per_round"] == 1.0
    assert shard["dense_merges_in_engine"] == 0
    assert shard["oracle_loss_max_err"] <= 1e-5, shard["oracle_loss_max_err"]
    base = run_arch_round(cfg)
    np.testing.assert_allclose(shard["loss_per_round"],
                               base["loss_per_round"], atol=1e-5)
    print("RAGGED_SHARD_OK", shard["loss_per_round"])
""")


@pytest.mark.multidevice
@pytest.mark.slow
def test_ragged_cohort_ghost_padded_8dev():
    import os
    proc = subprocess.run([sys.executable, "-c", RAGGED_SHARD_SUBPROC],
                          capture_output=True, text=True, timeout=1800,
                          env={**os.environ, "PYTHONPATH": "src"})
    assert "RAGGED_SHARD_OK" in proc.stdout, (proc.stdout,
                                              proc.stderr[-3000:])
