"""Hypothesis property tests on system invariants (deliverable c):
aggregation algebra, LoRA merge equivalence, channel monotonicity,
Dirichlet partition completeness, optimizer behavior."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import trees
from repro.core.aggregation import fedavg, masked_fedavg, partial_fedavg

sane = st.floats(-100, 100, allow_nan=False, width=32)


def _tree(vals):
    a, b, c = vals
    return {"x": {"w": jnp.full((2, 3), a)}, "y": jnp.full((4,), b),
            "adapter": {"wd": jnp.full((3,), c)}}


@given(st.tuples(sane, sane, sane), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_fedavg_of_identical_trees_is_identity(vals, n):
    t = _tree(vals)
    agg = fedavg([t] * n)
    for k, v in trees.flatten(agg).items():
        np.testing.assert_allclose(np.asarray(v),
                                   np.asarray(trees.flatten(t)[k]),
                                   rtol=1e-5, atol=1e-30)


@given(st.lists(st.tuples(sane, sane, sane), min_size=2, max_size=5))
@settings(max_examples=25, deadline=None)
def test_fedavg_within_convex_hull(vals_list):
    ts = [_tree(v) for v in vals_list]
    agg = trees.flatten(fedavg(ts))
    for k in agg:
        leaves = np.stack([np.asarray(trees.flatten(t)[k]) for t in ts])
        assert (np.asarray(agg[k]) <= leaves.max(0) + 1e-3).all()
        assert (np.asarray(agg[k]) >= leaves.min(0) - 1e-3).all()


@given(st.tuples(sane, sane, sane), st.tuples(sane, sane, sane))
@settings(max_examples=25, deadline=None)
def test_partial_fedavg_touches_only_selected(g, c):
    glob, client = _tree(g), _tree(c)
    out = partial_fedavg(glob, [client],
                         pred=lambda p: p.startswith("adapter"))
    fo, fg, fc = trees.flatten(out), trees.flatten(glob), trees.flatten(client)
    for k in fo:
        if k.startswith("adapter"):
            np.testing.assert_allclose(np.asarray(fo[k]), np.asarray(fc[k]),
                                       rtol=1e-5, atol=1e-30)
        else:
            np.testing.assert_allclose(np.asarray(fo[k]), np.asarray(fg[k]),
                                       rtol=1e-5, atol=1e-30)


@given(st.tuples(sane, sane, sane), st.tuples(sane, sane, sane))
@settings(max_examples=25, deadline=None)
def test_masked_fedavg_keeps_global_under_zero_mask(g, c):
    glob, client = _tree(g), _tree(c)
    zeros = jax.tree_util.tree_map(lambda x: jnp.zeros(()), glob)
    out = masked_fedavg(glob, [client], [zeros])
    for k, v in trees.flatten(out).items():
        np.testing.assert_allclose(np.asarray(v),
                                   np.asarray(trees.flatten(glob)[k]),
                                   rtol=1e-5, atol=1e-30)


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 5.0))
@settings(max_examples=10, deadline=None)
def test_dirichlet_partition_complete_and_disjoint(seed, alpha):
    from repro.data.partition import dirichlet_partition
    rng = np.random.RandomState(seed % 1000)
    labels = rng.randint(0, 4, size=200)
    parts = dirichlet_partition(labels, 4, alpha, seed=seed % 1000)
    allidx = np.concatenate(parts)
    assert len(allidx) == 200
    assert len(np.unique(allidx)) == 200


@given(st.floats(-10, 30), st.floats(-10, 30), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_channel_rate_monotone_in_snr(snr1, snr2, seed):
    from repro.wireless import RayleighChannel
    lo, hi = sorted([snr1, snr2])
    g = np.random.RandomState(seed).exponential()
    r_lo = RayleighChannel(mean_snr_db=lo, seed=seed).uplink(1000, gain=g)
    r_hi = RayleighChannel(mean_snr_db=hi, seed=seed).uplink(1000, gain=g)
    assert r_hi.rate_bps >= r_lo.rate_bps - 1e-6


@given(st.integers(1, 6), st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_lora_merge_equivalence(rank, seed):
    """apply_lora(W, {A,B}) forward == W·x + s·B(A(x)) for random factors."""
    from repro.models.peft import PEFTConfig, apply_lora
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    pc = PEFTConfig(lora_rank=rank, lora_alpha=2.0 * rank,
                    lora_targets=("mixer/wq",))
    w = jax.random.normal(ks[0], (8, 8))
    params = {"stages": [{"layers": [{"mixer": {"wq": w}}]}]}
    lora = {"stages": [{"layers": [{"mixer": {"wq": {
        "a": jax.random.normal(ks[1], (8, rank)),
        "b": jax.random.normal(ks[2], (rank, 8)),
        "mask": jnp.ones(())}}}]}]}
    eff = apply_lora(params, lora, pc)
    x = jax.random.normal(ks[3], (4, 8))
    got = x @ eff["stages"][0]["layers"][0]["mixer"]["wq"]
    l = lora["stages"][0]["layers"][0]["mixer"]["wq"]
    want = x @ w + 2.0 * (x @ l["a"]) @ l["b"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@given(st.floats(0.0, 0.9))
@settings(max_examples=10, deadline=None)
def test_head_sparsity_mask_fraction(sparsity):
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.peft import head_sparsity_mask
    from repro.sharding import MeshCtx
    cfg = get_config("gpt2-small").reduced()
    model = Model(cfg, meshctx=MeshCtx.single_device())
    params = model.init(jax.random.PRNGKey(0))
    mask = head_sparsity_mask(params, cfg, sparsity, seed=0)
    wq_mask = trees.flatten(mask)["stages/0/layers/0/mixer/wq"]
    frac = float(np.asarray(wq_mask).mean())
    n_keep = max(1, int(round(cfg.n_heads * (1.0 - sparsity))))
    assert abs(frac - n_keep / cfg.n_heads) < 1e-6
