"""Launcher-layer tests: step builders run on CPU, jaxpr cost counter is
consistent, dry-run helpers behave."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.launch.jaxpr_cost import count_flops, step_flops
from repro.launch.steps import (make_input_batch_shapes, make_peft_step,
                                make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import Model
from repro.models import peft as peft_mod
from repro.sharding import MeshCtx
from repro import trees

MESH = MeshCtx.single_device()


def _tiny():
    cfg = get_config("tinyllama-1.1b").reduced(d_model=64, repeats=2)
    return cfg, Model(cfg, meshctx=MESH)


def _batch(cfg, b=2, s=32):
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks, "mask": jnp.ones((b, s))}


def test_train_step_decreases_loss():
    cfg, model = _tiny()
    params = model.init(jax.random.PRNGKey(0))
    step_fn, opt = make_train_step(model, lr=5e-3)
    opt_state = opt.init(params)
    batch = _batch(cfg)
    jstep = jax.jit(step_fn)
    losses = []
    for _ in range(8):
        params, opt_state, loss = jstep(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_peft_step_only_touches_trainable():
    cfg, model = _tiny()
    base = model.init(jax.random.PRNGKey(0))
    pc = peft_mod.PEFTConfig(lora_rank=4, adapter_dim=8)
    params = peft_mod.init_adapters(jax.random.PRNGKey(1), base, cfg, pc)
    lora = peft_mod.init_lora(jax.random.PRNGKey(2), params, pc)
    adapters = trees.select(params, peft_mod.is_adapter_path)
    trainable = {"adapters": adapters, "lora": lora}
    step_fn, opt = make_peft_step(model, pc, lr=5e-3)
    opt_state = opt.init(trainable)
    t2, _, loss = jax.jit(step_fn)(trainable, params, opt_state, _batch(cfg))
    assert np.isfinite(float(loss))
    # adapters moved
    moved = trees.flatten(jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).sum()),
        trainable["adapters"], t2["adapters"]))
    assert any(v and v > 0 for v in moved.values() if v is not None)


def test_prefill_and_serve_steps():
    cfg, model = _tiny()
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, s=16)
    prefill = make_prefill_step(model, cache_len=32)
    logits, cache = jax.jit(prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    serve = make_serve_step(model)
    lg, cache = jax.jit(serve)(params, cache, batch["tokens"][:, :1])
    assert lg.shape == (2, cfg.vocab_size)
    assert int(cache["pos"]) == 17


def test_input_batch_shapes_all_archs():
    from repro.configs import ASSIGNED
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            b = make_input_batch_shapes(cfg, shape)
            assert "tokens" in b
            if cfg.n_prefix_tokens:
                assert b["patches"].shape[1] == cfg.n_prefix_tokens
                assert b["tokens"].shape[1] == shape.seq_len - cfg.n_prefix_tokens
            if cfg.is_encoder_decoder:
                assert b["frames"].shape[1] == cfg.encoder_seq


def test_jaxpr_flop_counter_matmul_exact():
    def f(a, b):
        return a @ b
    flops = step_flops(f, jax.ShapeDtypeStruct((8, 16), jnp.float32),
                       jax.ShapeDtypeStruct((16, 32), jnp.float32))
    assert flops == 2 * 8 * 16 * 32


def test_jaxpr_flop_counter_scan_multiplies():
    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    flops = step_flops(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    assert flops >= 7 * 2 * 8 * 8 * 8


def test_jaxpr_flop_counter_remat_counts_recompute():
    def loss(w, x):
        @jax.checkpoint
        def block(h):
            return jnp.tanh(h @ w)
        h = block(x)
        h = block(h)
        return h.sum()

    def train(w, x):
        return jax.grad(loss)(w, x)

    base = step_flops(lambda w, x: loss(w, x),
                      jax.ShapeDtypeStruct((16, 16), jnp.float32),
                      jax.ShapeDtypeStruct((4, 16), jnp.float32))
    grad = step_flops(train, jax.ShapeDtypeStruct((16, 16), jnp.float32),
                      jax.ShapeDtypeStruct((4, 16), jnp.float32))
    assert grad > 2 * base  # bwd + remat recompute


def test_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
      %ag = bf16[4,128]{1,0} all-gather(bf16[2,128]{1,0} %x), dimensions={0}
      %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
      %a2a = bf16[8,64]{1,0} all-to-all(bf16[8,64]{1,0} %z), dimensions={0}
    """
    detail, wire = parse_collective_bytes(hlo)
    assert detail["all-gather"] == 4 * 128 * 2
    assert detail["all-reduce"] == 256 * 4
    assert detail["all-to-all"] == 8 * 64 * 2
    assert wire == 2 * 256 * 4 + 4 * 128 * 2 + 8 * 64 * 2
