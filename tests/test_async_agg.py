"""Tests for the §VI open-issue implementations: async aggregation,
fair selection, quantized uplinks."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.async_agg import (FairSelector, StalenessWeightedAggregator,
                                  dequantize_update, quantize_update,
                                  quantized_bytes)
from repro import trees


def test_staleness_discounts_old_updates():
    g = {"w": jnp.zeros(3)}
    agg = StalenessWeightedAggregator(global_tree=g, alpha=0.5, a=1.0)
    agg.submit({"w": jnp.ones(3)}, produced_round=0)   # fresh
    fresh = agg.step()["w"][0]
    agg2 = StalenessWeightedAggregator(global_tree=g, alpha=0.5, a=1.0,
                                       round=5)
    agg2.submit({"w": jnp.ones(3)}, produced_round=0)  # staleness 5
    stale = agg2.step()["w"][0]
    assert float(fresh) > float(stale) > 0.0


def test_async_converges_to_target():
    g = {"w": jnp.zeros(1)}
    agg = StalenessWeightedAggregator(global_tree=g, alpha=0.6)
    for r in range(40):
        agg.submit({"w": jnp.ones(1) * 2.0}, produced_round=r)
        agg.step()
    assert abs(float(agg.global_tree["w"][0]) - 2.0) < 1e-3


def test_async_merge_is_permutation_invariant():
    """Same round's arrivals must merge identically regardless of submit
    order (the old sequential pairwise merge gave later submissions more
    influence)."""
    updates = [({"w": jnp.ones(3) * v}, r)
               for v, r in [(1.0, 0), (5.0, 2), (-2.0, 3)]]
    outs = []
    for perm in ([0, 1, 2], [2, 1, 0], [1, 2, 0]):
        agg = StalenessWeightedAggregator(
            global_tree={"w": jnp.zeros(3)}, alpha=0.5, a=0.7, round=4)
        for i in perm:
            agg.submit(*updates[i])
        outs.append(np.asarray(agg.step()["w"]))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_async_single_update_matches_pairwise_merge():
    g = {"w": jnp.zeros(2)}
    agg = StalenessWeightedAggregator(global_tree=g, alpha=0.6, a=0.5,
                                      round=3)
    agg.submit({"w": jnp.ones(2)}, produced_round=1)
    w = 0.6 * (1.0 + 2) ** (-0.5)
    np.testing.assert_allclose(np.asarray(agg.step()["w"]),
                               np.full(2, w), rtol=1e-6)


def test_quantized_bytes_skips_none_leaves():
    """Leaves that don't ship (``None`` — e.g. a frozen subtree hole) must
    not be charged a scale on the wire."""
    q = {"a": np.zeros(10, np.int8), "b": None}
    assert quantized_bytes(q) == 10 + 4          # one payload + ONE scale
    assert quantized_bytes({"b": None}) == 0     # nothing ships, zero bytes


def test_fair_selector_serves_everyone():
    rng = np.random.RandomState(0)
    sel = FairSelector(n_clients=8)
    counts = np.zeros(8)
    for _ in range(200):
        rates = rng.exponential(1.0, 8)
        rates[3] *= 0.2  # client 3 has chronically bad channel
        for c in sel.select(rates, k=2):
            counts[c] += 1
    assert counts.min() > 0, counts
    # PF keeps even the weak client within a reasonable share
    assert counts[3] >= 0.25 * counts.mean(), counts


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_quantization_roundtrip_error_bounded(seed):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (16, 8)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (5,))}}
    q, scales = quantize_update(tree)
    out = dequantize_update(q, scales, tree)
    for path, leaf in trees.flatten(tree).items():
        err = np.abs(np.asarray(out and trees.flatten(out)[path]) -
                     np.asarray(leaf)).max()
        scale = scales[path]
        assert err <= scale * 0.5 + 1e-7   # half-ulp of int8 grid


def test_quantized_bytes_4x_smaller():
    tree = {"w": jnp.ones((64, 64), jnp.float32)}
    q, _ = quantize_update(tree)
    from repro.wireless import tree_bytes
    assert quantized_bytes(q) < tree_bytes(tree) / 3.9


def test_quantized_fedavg_still_converges():
    """FedAvg over int8-quantized uploads reaches the clients' mean."""
    from repro.core.aggregation import fedavg
    rng = np.random.RandomState(0)
    targets = [rng.randn(4).astype(np.float32) for _ in range(4)]
    uploads = []
    for t in targets:
        q, s = quantize_update({"w": jnp.asarray(t)})
        uploads.append(dequantize_update(q, s, {"w": jnp.asarray(t)}))
    agg = fedavg(uploads)
    np.testing.assert_allclose(np.asarray(agg["w"]),
                               np.mean(targets, axis=0), atol=0.02)
