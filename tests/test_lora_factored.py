"""Factored (unmerged) LoRA execution vs the merged oracle.

The factored path (``peft.lora_proj`` threaded through the model as a side
channel) must reproduce ``apply_lora``-merged execution exactly — forward
activations, factor gradients, prefill/decode logits — including partial
``lora_layers`` masks and GQA (n_kv_heads < n_heads) targets, and the
Pallas serving lowering must agree with the jnp path.  End-to-end, the
factored PFTT run must match the merged-oracle run round-for-round, and
per-round cohort eval must be ONE fused dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import trees
from repro.configs import get_config
from repro.models import Model
from repro.models import peft as peft_mod
from repro.sharding import MeshCtx

KEY = jax.random.PRNGKey(0)


def _randomize_factors(lora, seed=1):
    """init_lora zeros B (delta starts at 0); give every factor leaf real
    values so parity actually exercises the low-rank path."""
    def rnd(x):
        if hasattr(x, "ndim") and x.ndim >= 2 and x.shape[-2:] != (1, 1):
            return jax.random.normal(jax.random.fold_in(KEY, seed),
                                     x.shape) * 0.05
        return x
    return jax.tree_util.tree_map(rnd, lora)


def _mk(arch, d_model=32, repeats=3, targets=("mixer/wq", "mixer/wv"),
        lora_layers=0, rank=4):
    mcfg = get_config(arch).reduced(d_model=d_model, repeats=repeats)
    model = Model(mcfg, meshctx=MeshCtx.single_device())
    params = model.init(KEY, max_seq=64)
    pc = peft_mod.PEFTConfig(lora_rank=rank, lora_alpha=2.0 * rank,
                             lora_targets=targets, lora_layers=lora_layers)
    lora = _randomize_factors(peft_mod.init_lora(KEY, params, pc))
    return mcfg, model, params, pc, lora


# ---------------------------------------------------------------------------
# forward / gradient parity (encoder-only = the PFTT backbone)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lora_layers", [0, 2])
def test_forward_parity_encoder(lora_layers):
    mcfg, model, params, pc, lora = _mk(
        "roberta-base", lora_layers=lora_layers,
        targets=("mixer/wq", "mixer/wv", "mixer/wo", "ff/wu", "ff/wd"))
    toks = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 16), 0,
                              mcfg.vocab_size)
    merged = peft_mod.apply_lora(params, lora, pc)
    h_m, _ = model.forward(merged, toks)
    h_f, _ = model.forward(params, toks, lora=lora,
                           lora_scale=peft_mod.lora_scale(pc))
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_m), atol=1e-5)


def test_grad_parity_encoder():
    mcfg, model, params, pc, lora = _mk(
        "roberta-base", lora_layers=2,
        targets=("mixer/wq", "mixer/wv", "mixer/wo"))
    batch = {"tokens": jax.random.randint(jax.random.fold_in(KEY, 2),
                                          (2, 16), 0, mcfg.vocab_size),
             "label": jnp.asarray([1, 2])}
    scale = peft_mod.lora_scale(pc)
    gm = jax.grad(lambda lo: model.cls_loss(
        peft_mod.apply_lora(params, lo, pc), batch)[0])(lora)
    gf = jax.grad(lambda lo: model.cls_loss(
        params, batch, lora=lo, lora_scale=scale)[0])(lora)
    flat_f = trees.flatten(gf)
    for path, gmv in trees.flatten(gm).items():
        np.testing.assert_allclose(np.asarray(flat_f[path]), np.asarray(gmv),
                                   atol=1e-6, err_msg=path)


def test_forward_parity_gqa_decoder():
    """GQA: wk/wv project to n_kv_heads·hd < n_heads·hd — factored factors
    mirror the rectangular leaves."""
    import dataclasses
    mcfg = dataclasses.replace(
        get_config("llama3.2-1b").reduced(d_model=32, repeats=2),
        n_kv_heads=2)                       # force real grouped-query
    assert mcfg.n_kv_heads < mcfg.n_heads
    model = Model(mcfg, meshctx=MeshCtx.single_device())
    params = model.init(KEY, max_seq=64)
    pc = peft_mod.PEFTConfig(
        lora_rank=4, lora_alpha=8.0,
        lora_targets=("mixer/wq", "mixer/wk", "mixer/wv", "mixer/wo"))
    lora = _randomize_factors(peft_mod.init_lora(KEY, params, pc))
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (2, 12), 0,
                              mcfg.vocab_size)
    merged = peft_mod.apply_lora(params, lora, pc)
    h_m, _ = model.forward(merged, toks)
    h_f, _ = model.forward(params, toks, lora=lora,
                           lora_scale=peft_mod.lora_scale(pc))
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_m), atol=1e-5)


# ---------------------------------------------------------------------------
# serving parity: prefill + decode, jnp and Pallas lowering
# ---------------------------------------------------------------------------


def test_prefill_decode_parity_and_pallas():
    mcfg, model, params, pc, lora = _mk("gpt2-small", repeats=2)
    scale = peft_mod.lora_scale(pc)
    prompts = jnp.asarray(np.random.RandomState(0).randint(6, 50, (2, 8)))
    merged = peft_mod.apply_lora(params, lora, pc)
    lg_m, c_m = model.prefill(merged, prompts, cache_len=12)
    lg_f, c_f = model.prefill(params, prompts, cache_len=12, lora=lora,
                              lora_scale=scale)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_m), atol=1e-4)
    tok = jnp.argmax(lg_m, -1)[:, None].astype(jnp.int32)
    d_m, _ = model.decode_step(merged, c_m, tok)
    d_f, _ = model.decode_step(params, c_f, tok, lora=lora, lora_scale=scale)
    np.testing.assert_allclose(np.asarray(d_f), np.asarray(d_m), atol=1e-4)

    # the fused Pallas kernel is the serving lowering of the same contract
    model_p = Model(mcfg, meshctx=MeshCtx.single_device(),
                    opts={"lora_backend": "pallas"})
    lg_p, c_p = model_p.prefill(params, prompts, cache_len=12, lora=lora,
                                lora_scale=scale)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_f), atol=1e-5)
    d_p, _ = model_p.decode_step(params, c_p, tok, lora=lora,
                                 lora_scale=scale)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_f), atol=1e-5)


def test_non_stage_lora_targets_rejected_on_factored_path():
    """Factors outside the layer stacks (e.g. cls_head) would be silently
    ignored by the side channel — the model must refuse them at trace time
    (the merged oracle apply_lora still supports such targets)."""
    mcfg, model, params, pc, lora = _mk("roberta-base", repeats=2,
                                        targets=("cls_head",))
    toks = jax.random.randint(jax.random.fold_in(KEY, 4), (2, 8), 0,
                              mcfg.vocab_size)
    with pytest.raises(ValueError, match="factored LoRA"):
        model.forward(params, toks, lora=lora, lora_scale=1.0)


def test_lora_proj_pallas_nonaligned_shapes():
    """The kernel must accept the model's real (non-128-multiple) dims."""
    from repro.models.peft import lora_proj
    k = jax.random.split(KEY, 4)
    x = jax.random.normal(k[0], (3, 7, 48))
    w = jax.random.normal(k[1], (48, 36)) * 0.1
    lf = {"a": jax.random.normal(k[2], (48, 4)) * 0.1,
          "b": jax.random.normal(k[3], (4, 36)) * 0.1,
          "mask": jnp.ones(())}
    ref = lora_proj(x, w, lf, scale=2.0)
    out = lora_proj(x, w, lf, scale=2.0, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: factored vs merged oracle + O(1)-dispatch cohort eval
# ---------------------------------------------------------------------------


def test_pftt_factored_matches_merged_oracle():
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(n_clients=2, rounds=3, local_steps=2, pretrain_steps=10,
              samples_per_client=120, d_model=32, seed=0)
    fac = run_pftt(PFTTConfig(factored=True, **kw))
    mrg = run_pftt(PFTTConfig(factored=False, **kw))
    np.testing.assert_allclose(fac["acc_per_round"], mrg["acc_per_round"],
                               atol=1e-5)
    assert fac["mean_round_bytes"] == mrg["mean_round_bytes"]
    # engine-side eval: the whole cohort is scored in ONE fused vmapped
    # dispatch per round, regardless of cohort size or ragged test sets
    assert fac["eval_dispatches_per_round"] == 1
    assert mrg["eval_dispatches_per_round"] == 1


def test_cohort_eval_padded_matches_per_client():
    """build_cohort_eval over a padded/masked stacked test set reproduces
    per-client eval exactly (correct counts are integers)."""
    from repro.core.cohort import build_cohort_eval
    mcfg, model, params, pc, lora = _mk("roberta-base", repeats=2)
    rng = np.random.RandomState(0)
    sizes = [5, 3]                       # ragged test sets
    max_n = max(sizes)
    toks = np.zeros((2, max_n, 12), np.int32)
    labels = np.zeros((2, max_n), np.int32)
    valid = np.zeros((2, max_n), np.float32)
    per_client = []
    for ci, n in enumerate(sizes):
        t = rng.randint(0, mcfg.vocab_size, (n, 12))
        l = rng.randint(0, mcfg.n_classes, (n,))
        toks[ci, :n], labels[ci, :n], valid[ci, :n] = t, l, 1.0
        per_client.append((t, l))

    def eval_client(trainable, tk, lb, vd):
        h, _ = model.forward(trainable, tk)
        pred = (h[:, 0] @ trainable["cls_head"]).astype(
            jnp.float32).argmax(-1)
        return ((pred == lb).astype(jnp.float32) * vd).sum(), vd.sum()

    ev = build_cohort_eval(eval_client)
    corr, cnt = ev(trees.stack([params, params]), jnp.asarray(toks),
                   jnp.asarray(labels), jnp.asarray(valid))
    for ci, (t, l) in enumerate(per_client):
        h, _ = model.forward(params, jnp.asarray(t))
        pred = (h[:, 0] @ params["cls_head"]).astype(jnp.float32).argmax(-1)
        assert int(corr[ci]) == int((np.asarray(pred) == l).sum())
        assert int(cnt[ci]) == len(l)


def test_host_batch_stacker_reuses_buffer():
    from repro.core.cohort import HostBatchStacker
    stacker = HostBatchStacker()
    mk = lambda v: [[{"x": np.full((2, 3), v + 10 * ci + si, np.float32)}
                     for si in range(2)] for ci in range(2)]
    out1 = stacker(mk(0.0))
    buf_id = id(stacker._bufs["x"])
    out2 = stacker(mk(1.0))
    assert id(stacker._bufs["x"]) == buf_id          # no realloc
    assert out1["x"].shape == (2, 2, 2, 3)
    np.testing.assert_array_equal(np.asarray(out2["x"])[1, 1],
                                  np.full((2, 3), 12.0))
