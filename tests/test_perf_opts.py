"""Correctness of the §Perf beyond-paper optimizations: each optimized path
must match its baseline implementation exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SparseAttnConfig
from repro.models import attention as A


@pytest.fixture
def qkv():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    return (jax.random.normal(ks[0], (2, 256, 8, 32)),
            jax.random.normal(ks[1], (2, 256, 4, 32)),
            jax.random.normal(ks[2], (2, 256, 4, 32)))


@pytest.mark.parametrize("window", [0, 80])
def test_pairs_attention_matches_dense(qkv, window):
    q, k, v = qkv
    want = A.dense_attention(q, k, v, causal=True, window=window)
    got = A.chunked_attention_pairs(q, k, v, causal=True, window=window,
                                    q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_pairs_attention_differentiable(qkv):
    q, k, v = qkv
    g = jax.grad(lambda q: A.chunked_attention_pairs(
        q, k, v, q_block=64, kv_block=64).astype(jnp.float32).sum())(q)
    assert bool(jnp.isfinite(g).all())


def test_sparse_gather_decode_matches_masked(qkv):
    q, k, v = qkv
    scfg = SparseAttnConfig(block_size=16, local_blocks=2, sink_blocks=1,
                            stride=4)
    for pos in (0, 17, 100, 255):
        want = A.decode_attention(q[:, pos:pos + 1], k, v, cache_len=pos + 1,
                                  sparse=scfg)
        got = A.sparse_gather_decode(q[:, pos:pos + 1], k, v,
                                     jnp.asarray(pos), scfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)


def test_sparse_kv_cache_full_sweep(qkv):
    """Exhaustive positional sweep: sparse KV cache == masked dense attention
    over the realized pattern (pers blocks + block-aligned local band)."""
    q, k, v = qkv
    scfg = SparseAttnConfig(block_size=16, local_blocks=2, sink_blocks=1,
                            stride=4)
    S = 256
    pers_blocks, _, ring_slots, n_pers = A.sparse_kv_layout(S, scfg)
    cache = {n: jnp.zeros((2, sz, 4, 32)) for n, sz in
             [("k_pers", n_pers), ("v_pers", n_pers),
              ("k_ring", ring_slots), ("v_ring", ring_slots)]}
    for pos in range(S):
        cache = A.sparse_kv_write(cache, k[:, pos:pos + 1], v[:, pos:pos + 1],
                                  jnp.asarray(pos), scfg, S)
        if pos % 23 != 0 and pos != S - 1:
            continue
        got = A.sparse_kv_decode(q[:, pos:pos + 1], cache, jnp.asarray(pos),
                                 scfg, S)
        qblk = pos // 16
        mask = np.zeros(S, bool)
        for blk in pers_blocks:
            if blk <= qblk - scfg.local_blocks - 1:
                mask[blk * 16:(blk + 1) * 16] = True
        mask[max(0, (qblk - scfg.local_blocks) * 16):pos + 1] = True
        want = A.dense_attention(q[:, pos:pos + 1], k, v, causal=False,
                                 mask=jnp.asarray(mask[None, :]))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4, err_msg=f"pos={pos}")


def test_sparse_kv_cache_is_smaller():
    scfg = SparseAttnConfig()  # block 128, stride 8
    _, _, ring, n_pers = A.sparse_kv_layout(524288, scfg)
    assert (n_pers + ring) < 524288 / 6  # ≥6× memory reduction


def test_moe_a2a_matches_replicated_single_device():
    from repro.configs.base import MoEConfig
    from repro.models.moe import init_moe, moe_ffn, moe_ffn_a2a
    from repro.sharding import MeshCtx
    mc = MeshCtx.single_device()
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=4.0)
    p = init_moe(jax.random.PRNGKey(0), 16, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y1, _ = moe_ffn(x, p, cfg, mc, "swiglu")
    y2, _ = moe_ffn_a2a(x, p, cfg, mc, "swiglu")  # falls back on 1 device
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_distributed_opts_match_8dev():
    """a2a MoE + seq-parallel SSD numerics on a real 8-device mesh."""
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding import MeshCtx, use_mesh
        from repro.models.moe import init_moe, moe_ffn, moe_ffn_a2a
        from repro.models.ssm import init_mamba, mamba_seq, mamba_seq_sp
        from repro.configs.base import MoEConfig, SSMConfig
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        mc = MeshCtx(mesh=mesh, batch_axes=("data",))
        key = jax.random.PRNGKey(0)
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=64, capacity_factor=4.0)
        p = init_moe(key, 32, cfg, "swiglu", jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, 32))
        with use_mesh(mesh):
            y1, _ = jax.jit(lambda x: moe_ffn(x, p, cfg, mc, "swiglu"))(x)
            y2, _ = jax.jit(lambda x: moe_ffn_a2a(x, p, cfg, mc, "swiglu"))(x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
        scfg = SSMConfig(state=16, headdim=8, expand=2, chunk=8, conv_width=4)
        pm = init_mamba(key, 32, scfg, jnp.float32)
        xm = jax.random.normal(jax.random.fold_in(key, 2), (4, 64, 32))
        with use_mesh(mesh):
            y_sp = jax.jit(lambda x: mamba_seq_sp(x, pm, scfg, 32, 1e-5, mc))(xm)
        y_ref, _ = mamba_seq(xm, pm, scfg, 32, 1e-5)
        np.testing.assert_allclose(np.asarray(y_sp), np.asarray(y_ref),
                                   atol=2e-5, rtol=1e-4)
        print("DIST_OPTS_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": "/root"})
    assert "DIST_OPTS_OK" in proc.stdout, proc.stderr[-3000:]
