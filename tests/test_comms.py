"""Uplink codec subsystem tests (``repro.comms``).

Covers: stochastic-rounding quantizer error bounds and unbiasedness under a
fixed PRNG key schedule, entropy-based bit accounting bounds, top-k exact
recovery on sparse trees, count-sketch heavy-hitter recovery on
top-k-dominated signals, SVD re-projection parity against the dense-merge
oracle on fedlora-shaped factors (≤1e-5, no densification on the server
path), codec-under-``shard_map`` parity with ghost-padded non-divisible
cohorts, ``ChannelBudget`` delay/energy accounting + the all-outage NaN
delay fix, ``tree_bytes`` itemsize overrides and treedef pairing, and
engine-vs-legacy-loop ledger agreement with a codec active."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import trees
from repro.comms import (ChannelBudget, dense_rank_r_oracle, get_codec,
                         payload_bits_upper_bound, roundtrip, svd_reproject)
from repro.comms import quantize, sketch
from repro.comms.factored_agg import factored_fedavg_tree
from repro.core.aggregation import fedavg_stacked
from repro.core.cohort import build_supervised_round
from repro.optim import sgd
from repro.wireless import CommLedger, RayleighChannel, tree_bytes
from repro.wireless.channel import ChannelReport


def _key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# stochastic-rounding quantization (comms.quantize)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_sr_quantize_roundtrip_error_bound(bits):
    """|decode - x| ≤ per-channel scale, elementwise (one SR step can move
    at most one quantization level)."""
    r = np.random.RandomState(0)
    x = jnp.asarray(r.randn(6, 33) * 0.3, jnp.float32)
    enc = quantize.sr_quantize(_key(1), x, bits)
    dec = quantize.sr_dequantize(enc)
    bound = np.broadcast_to(np.asarray(enc["scale"]), x.shape) * 1.0001
    assert (np.abs(np.asarray(dec - x)) <= bound + 1e-8).all()


@pytest.mark.parametrize("bits", [8, 4])
def test_sr_quantize_unbiased(bits):
    """E[decode] = x under stochastic rounding: averaging decodes over many
    fixed PRNG keys converges to the input."""
    r = np.random.RandomState(1)
    x = jnp.asarray(r.randn(4, 16) * 0.1, jnp.float32)

    def dec(i):
        return quantize.sr_dequantize(
            quantize.sr_quantize(jax.random.fold_in(_key(2), i), x, bits))

    n = 1500
    mean = np.mean([np.asarray(dec(i)) for i in range(n)], axis=0)
    scale = np.broadcast_to(np.asarray(
        quantize.channel_scale(x, bits)), x.shape)
    # CLT: SR noise per draw is Bernoulli-f within a level → var f(1-f) ≤ ¼,
    # so |mean - x| ≲ 4σ = 4·scale·½/√n for ≳99.99% of elements
    tol = 2.0 * scale / np.sqrt(n) + 1e-7
    assert (np.abs(mean - np.asarray(x)) <= tol).mean() > 0.99


def test_sr_quantize_zero_channels_exact():
    x = jnp.zeros((8, 8), jnp.float32)
    dec = quantize.sr_dequantize(quantize.sr_quantize(_key(), x, 8))
    np.testing.assert_array_equal(np.asarray(dec), 0.0)


@pytest.mark.parametrize("bits", [8, 4])
def test_entropy_bits_bounded_by_flat_bits(bits):
    r = np.random.RandomState(2)
    x = jnp.asarray(r.randn(32, 32), jnp.float32)
    enc = quantize.sr_quantize(_key(3), x, bits)
    ent = float(quantize.symbol_entropy_bits(enc["q"], bits))
    assert 0.0 < ent <= x.size * bits + 1e-6


def test_entropy_bits_mask_restricts_charge():
    r = np.random.RandomState(3)
    x = jnp.asarray(r.randn(16, 16), jnp.float32)
    enc = quantize.sr_quantize(_key(4), x, 8)
    full = float(quantize.symbol_entropy_bits(enc["q"], 8))
    m = jnp.zeros((16, 16)).at[:4].set(1.0)
    part = float(quantize.symbol_entropy_bits(enc["q"], 8, m))
    assert part < 0.5 * full


# ---------------------------------------------------------------------------
# sketches (comms.sketch)
# ---------------------------------------------------------------------------


def test_topk_exact_on_sparse_leaf():
    """A leaf with ≤k nonzeros decodes exactly (up to f16 value rounding)."""
    x = np.zeros((400,), np.float32)
    idx = np.asarray([3, 77, 200, 399])
    x[idx] = [1.5, -2.0, 0.25, 4.0]
    enc = sketch.topk_encode(jnp.asarray(x), frac=0.01)  # k = 4
    dec = np.asarray(sketch.topk_decode(enc, (400,)))
    np.testing.assert_allclose(dec, x, rtol=1e-3)


def test_count_sketch_recovers_heavy_hitters():
    """On a top-k-dominated signal the median-of-rows count-sketch estimate
    recovers the heavy coordinates within the collision-noise floor."""
    r = np.random.RandomState(4)
    x = r.randn(512).astype(np.float32) * 0.01          # background
    heavy_idx = r.choice(512, size=8, replace=False)
    x[heavy_idx] = np.sign(r.randn(8)) * 5.0            # heavy hitters
    enc = sketch.count_sketch_encode(jnp.asarray(x), leaf_seed=0, rows=5,
                                     ratio=0.5)
    dec = np.asarray(sketch.count_sketch_decode(enc, (512,), leaf_seed=0))
    # at worst one heavy hitter may lose its median to bucket collisions
    hits = np.abs(dec[heavy_idx] - x[heavy_idx]) < 0.5
    assert hits.sum() >= len(heavy_idx) - 1, dec[heavy_idx]
    # background coordinates stay near zero (collision-noise floor)
    bg = np.setdiff1d(np.arange(512), heavy_idx)
    assert np.median(np.abs(dec[bg])) < 0.25


def test_count_sketch_decode_is_linear_in_encode():
    """Same hashes on both sides: decode(encode(x)) is deterministic and
    jit-stable (server needs no negotiation traffic)."""
    x = jnp.asarray(np.random.RandomState(5).randn(128), jnp.float32)
    f = jax.jit(lambda v: sketch.count_sketch_decode(
        sketch.count_sketch_encode(v, leaf_seed=7, rows=3, ratio=0.25),
        (128,), leaf_seed=7))
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(f(x)))


# ---------------------------------------------------------------------------
# tree-level roundtrip + bit accounting (comms.codec)
# ---------------------------------------------------------------------------


def _fedlora_like_tree(seed=0, scale_a=0.09, scale_b=0.02):
    r = np.random.RandomState(seed)
    mk = lambda *s: jnp.asarray(r.randn(*s), jnp.float32)
    return {"base": {"cls_head": mk(64, 4)},
            "lora": {"wq": {"a": mk(2, 64, 8) * scale_a,
                            "b": mk(2, 8, 64) * scale_b,
                            "mask": jnp.ones((2, 1, 1), jnp.float32)},
                     "wv": {"a": mk(2, 64, 8) * scale_a,
                            "b": mk(2, 8, 64) * scale_b,
                            "mask": jnp.ones((2, 1, 1), jnp.float32)}}}


@pytest.mark.parametrize("name,min_ratio", [("int8", 3.5), ("int4", 6.0),
                                            ("sketch", 5.0)])
def test_roundtrip_compresses_fedlora_tree(name, min_ratio):
    tree = _fedlora_like_tree()
    ref = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jnp.asarray(
            np.random.RandomState(9).randn(*x.shape), jnp.float32), tree)
    codec = get_codec(name)
    dec, bits = jax.jit(
        lambda k, t, rf: roundtrip(codec, k, t, ref=rf))(_key(5), tree, ref)
    raw = sum(x.size * 32 for x in jax.tree_util.tree_leaves(tree))
    assert raw / float(bits) >= min_ratio, (name, raw / float(bits))
    # mask leaves are below MIN_CODED_SIZE: pass through exactly
    np.testing.assert_array_equal(
        np.asarray(dec["lora"]["wq"]["mask"]),
        np.asarray(tree["lora"]["wq"]["mask"]))
    # decode stays close to the true upload (deltas are small)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        trees.flatten(dec).values(), trees.flatten(tree).values()))
    assert err < 0.05, (name, err)


@pytest.mark.parametrize("name", ["int8", "int4", "sketch", "countsketch"])
def test_roundtrip_fully_masked_leaf_charges_zero_bits(name):
    """Weight-0 elements are not transmitted — a fully-masked leaf must
    charge 0 bits INCLUDING the per-channel scale / static sketch payload
    (the no-codec baseline ``tree_bytes(nonzero_mask=...)`` charges 0 for
    such leaves too, so ratios stay comparable)."""
    codec = get_codec(name)
    tree = {"w": jnp.asarray(np.random.RandomState(0).randn(64, 64),
                             jnp.float32)}
    masks = {"w": jnp.zeros((64, 64), jnp.float32)}
    ref = jax.tree_util.tree_map(jnp.zeros_like, tree)
    dec, bits = roundtrip(codec, _key(8), tree, ref=ref, bit_weights=masks)
    assert float(bits) == 0.0, (name, float(bits))
    # decode keeps the server-known reference on untransmitted lanes
    np.testing.assert_array_equal(np.asarray(dec["w"]), 0.0)
    # partial masks never charge more than the unmasked leaf (strictly less
    # for quantizers; sketches are already sublinear in n)
    half = {"w": jnp.zeros((64, 64)).at[:32].set(1.0)}
    _, b_half = roundtrip(codec, _key(8), tree, ref=ref, bit_weights=half)
    _, b_full = roundtrip(codec, _key(8), tree, ref=ref)
    assert 0.0 < float(b_half) <= float(b_full)
    if name in ("int8", "int4"):
        assert float(b_half) < float(b_full)


def test_roundtrip_entropy_bits_below_upper_bound():
    tree = _fedlora_like_tree()
    codec = get_codec("int8")
    _, bits = roundtrip(codec, _key(6), tree)
    assert float(bits) <= payload_bits_upper_bound(codec, tree) + 1e-3


def test_roundtrip_vmaps_over_clients():
    """The stacked-cohort form the engine uses: one vmapped dispatch, one
    bits scalar per client, per-client keys decorrelate the rounding."""
    tree = _fedlora_like_tree()
    st = trees.stack([tree, tree, tree])
    keys = jnp.stack([jax.random.fold_in(_key(7), i) for i in range(3)])
    codec = get_codec("int4")
    dec, bits = jax.vmap(lambda k, t: roundtrip(codec, k, t))(keys, st)
    assert bits.shape == (3,)
    a = np.asarray(trees.flatten(dec)["lora/wq/a"])
    assert not np.array_equal(a[0], a[1])   # different SR draws per client


# ---------------------------------------------------------------------------
# factored aggregation: SVD re-projection vs dense-merge oracle
# ---------------------------------------------------------------------------


def _factors(n=5, rep=2, d=96, r=8, seed=0):
    rng = np.random.RandomState(seed)
    st_a = jnp.asarray(rng.randn(n, rep, d, r) * d ** -0.5, jnp.float32)
    st_b = jnp.asarray(rng.randn(n, rep, r, d) * 0.02, jnp.float32)
    return st_a, st_b


@pytest.mark.parametrize("weights", [None, [1., 0., 1., .5, 0.]])
def test_svd_reprojection_matches_dense_oracle(weights):
    """A'·B' must equal the rank-r truncated SVD of the dense weighted-mean
    update Σ ŵ_i A_i·B_i to ≤1e-5 — computed via (d × n·r) QR factors only,
    the dense (d × d) matrix exists only inside the test oracle."""
    st_a, st_b = _factors()
    w = None if weights is None else jnp.asarray(weights)
    a2, b2 = svd_reproject(st_a, st_b, w)
    assert a2.shape == st_a.shape[1:] and b2.shape == st_b.shape[1:]
    oracle = dense_rank_r_oracle(st_a, st_b, w)
    err = float(jnp.abs(a2 @ b2 - oracle).max())
    assert err <= 1e-5, err


def test_svd_reprojection_beats_naive_factor_mean():
    """avg(A)·avg(B) ≠ avg(A·B): the re-projection approximates the true
    mean update strictly better than averaging factors elementwise."""
    st_a, st_b = _factors(seed=3)
    w = jnp.asarray([1., 1., 1., 1., 1.])
    ŵ = np.asarray(w) / np.asarray(w).sum()
    dense = np.einsum("n...dr,n...rf->...df",
                      np.asarray(st_a) * ŵ[:, None, None, None],
                      np.asarray(st_b))
    a2, b2 = svd_reproject(st_a, st_b, w)
    naive = np.asarray(fedavg_stacked({"a": st_a}, w)["a"]) @ \
        np.asarray(fedavg_stacked({"b": st_b}, w)["b"])
    err_svd = np.abs(np.asarray(a2 @ b2) - dense).max()
    err_naive = np.abs(naive - dense).max()
    assert err_svd < err_naive


def test_factored_fedavg_tree_mixes_pairs_and_plain_leaves():
    st = trees.stack([_fedlora_like_tree(i) for i in range(4)])
    w = jnp.asarray([1., 1., 0., 1.])
    out = factored_fedavg_tree(st, w)
    plain = fedavg_stacked(st, w)
    # non-factor leaves: plain weighted mean, bitwise
    np.testing.assert_array_equal(
        np.asarray(trees.flatten(out)["base/cls_head"]),
        np.asarray(trees.flatten(plain)["base/cls_head"]))
    # factor pairs: the re-projected product matches the dense oracle
    fo, fs = trees.flatten(out), trees.flatten(st)
    oracle = dense_rank_r_oracle(fs["lora/wq/a"], fs["lora/wq/b"], w)
    err = float(jnp.abs(fo["lora/wq/a"] @ fo["lora/wq/b"] - oracle).max())
    assert err <= 1e-5, err


# ---------------------------------------------------------------------------
# codec + factored aggregation inside the fused round, sharded, ghost-padded
# ---------------------------------------------------------------------------


def _toy_codec_round(codec, mesh=None, n_clients=3, factored_agg=True):
    opt = sgd(0.2)

    def local_step(tr, op, batch):
        loss, g = jax.value_and_grad(
            lambda t: jnp.sum((t["shared"]["lin"] - batch["tgt"]) ** 2)
            + jnp.sum((t["shared"]["fac"]["a"] @ t["shared"]["fac"]["b"]
                       - 0.1) ** 2)
            + jnp.sum((t["local"]["v"] - batch["tgt"]) ** 2))(tr)
        upd, op = opt.update(g, op, tr)
        return trees.tree_add(tr, upd), op, loss

    r = np.random.RandomState(0)
    trs = [{"shared": {"lin": jnp.asarray(r.randn(32), jnp.float32),
                       "fac": {"a": jnp.asarray(r.randn(24, 4) * 0.1,
                                                jnp.float32),
                               "b": jnp.asarray(r.randn(4, 24) * 0.1,
                                                jnp.float32)}},
            "local": {"v": jnp.zeros(32)}} for _ in range(n_clients)]
    st_tr = trees.stack(trs)
    st_op = trees.stack([opt.init(t) for t in trs])
    batches = {"tgt": jnp.asarray(np.stack(
        [np.full((3, 32), 1.0 + ci, np.float32)
         for ci in range(n_clients)]))}
    keys = jnp.stack([jax.random.fold_in(_key(11), i)
                      for i in range(n_clients)])
    step = build_supervised_round(local_step,
                                  lambda p: p.startswith("shared"),
                                  donate=False, codec=codec, mesh=mesh,
                                  factored_agg=factored_agg)
    return step, st_tr, st_op, batches, keys


def test_codec_round_sharded_one_device_mesh_matches_unsharded():
    """codec + factored_agg under shard_map (1-device ("pod","data") mesh)
    == the unsharded fused round — the collective math (psum + factor
    all-gather) collapses to the single-device math."""
    codec = get_codec("int8")
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    plain, st_tr, st_op, batches, keys = _toy_codec_round(codec)
    sharded, *_ = _toy_codec_round(codec, mesh=mesh)
    w = jnp.asarray([1.0, 0.0, 1.0])
    ref = plain(st_tr, st_op, batches, w, keys)
    got = sharded(st_tr, st_op, batches, w, keys)
    for (k, a), b in zip(trees.flatten(ref[0]).items(),
                         trees.flatten(got[0]).values()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=k)
    np.testing.assert_allclose(np.asarray(ref[3]), np.asarray(got[3]),
                               rtol=1e-6)


def test_codec_round_ghost_padding_invariance():
    """Zero-weight ghost clients (the sharded engine's non-divisible-cohort
    padding) must not change the real clients — including the codec's
    stochastic rounding and the factored aggregation."""
    codec = get_codec("int8")
    step, st_tr, st_op, batches, keys = _toy_codec_round(codec)
    ref = step(st_tr, st_op, batches, jnp.asarray([1.0, 0.0, 1.0]), keys)
    pad = lambda t: jax.tree_util.tree_map(
        lambda l: jnp.concatenate([l, l[:1]]), t)
    out4 = step(pad(st_tr), pad(st_op), pad(batches),
                jnp.asarray([1.0, 0.0, 1.0, 0.0]),
                jnp.concatenate([keys, keys[:1]]))
    for (k, a), b in zip(trees.flatten(ref[0]).items(),
                         trees.flatten(out4[0]).values()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[:3],
                                   atol=1e-6, err_msg=k)
    # ghost bits are produced but the round loop only reads the real rows
    assert np.asarray(out4[3]).shape == (4,)


def test_codec_round_all_outage_keeps_local():
    codec = get_codec("int8")
    step, st_tr, st_op, batches, keys = _toy_codec_round(codec)
    out, _, _, _ = step(st_tr, st_op, batches, jnp.zeros(3), keys)
    lin = np.asarray(trees.flatten(out)["shared/lin"])
    assert not np.allclose(lin[0], lin[1])     # gate: no agg, no broadcast


# ---------------------------------------------------------------------------
# ChannelBudget + CommLedger (bits → delay/energy; all-outage NaN delay)
# ---------------------------------------------------------------------------


def test_channel_budget_matches_channel_uplink():
    ch = RayleighChannel(mean_snr_db=5.0, seed=0)
    budget = ChannelBudget(ch, tx_power_w=0.25)
    rep = budget.report(8.0e6, gain=1.0)
    direct = ch.uplink(1.0e6, gain=1.0)
    assert rep.delay_s == direct.delay_s
    assert rep.bytes_sent == direct.bytes_sent
    np.testing.assert_allclose(rep.energy_j, 0.25 * rep.delay_s)


def test_channel_budget_outage_zero_energy_and_bytes():
    ch = RayleighChannel(mean_snr_db=5.0, seed=0)
    rep = ChannelBudget(ch).report(8.0e6, gain=1e-6)   # deep fade → outage
    assert rep.outage and rep.bytes_sent == 0 and rep.energy_j == 0.0


def test_ledger_all_outage_round_delay_is_nan_and_skipped():
    mk = lambda outage, delay: ChannelReport(
        snr_db=0.0, rate_bps=1.0, delay_s=delay, outage=outage,
        bytes_sent=0 if outage else 10)
    led = CommLedger()
    led.log_round([mk(True, np.inf), mk(True, np.inf)])   # all-outage
    led.log_round([mk(False, 2.0), mk(True, np.inf)])
    assert np.isnan(led.rounds[0]["delay_s"])
    assert led.mean_round_delay == 2.0                    # NaN skipped
    led2 = CommLedger()
    led2.log_round([mk(True, np.inf)])
    assert led2.mean_round_delay == 0.0                   # all rounds NaN


# ---------------------------------------------------------------------------
# tree_bytes: itemsize override + treedef pairing
# ---------------------------------------------------------------------------


def test_tree_bytes_itemsize_override():
    tree = {"w": jnp.zeros((10, 10), jnp.float32), "b": jnp.zeros(10)}
    assert tree_bytes(tree) == 440
    assert tree_bytes(tree, itemsize=1) == 110            # int8-quantized
    per_leaf = {"w": 0.5, "b": None}                      # int4 + raw f32
    assert tree_bytes(tree, itemsize=per_leaf) == 90


def test_tree_bytes_mask_pairs_by_treedef():
    tree = {"a": jnp.zeros((4, 4)), "b": jnp.zeros((8,))}
    mask = {"a": jnp.ones((4, 4)).at[0].set(0.0), "b": jnp.ones((8,))}
    assert tree_bytes(tree, nonzero_mask=mask) == (12 + 8) * 4
    with pytest.raises(ValueError):
        tree_bytes(tree, nonzero_mask={"a": mask["a"]})   # missing leaf
    with pytest.raises(ValueError):                       # extra leaf
        tree_bytes(tree, nonzero_mask=dict(mask, c=jnp.ones(2)))


# ---------------------------------------------------------------------------
# end-to-end: engine-vs-legacy-loop ledger agreement with a codec active
# ---------------------------------------------------------------------------


def test_pftt_codec_engine_matches_loop_including_ledger():
    """The fused round's vmapped codec must reproduce the legacy per-client
    roundtrip: accuracies AND ledger totals (encoded bytes, delay, energy)
    agree engine-vs-loop."""
    from repro.core.pftt import PFTTConfig, run_pftt
    kw = dict(n_clients=2, rounds=3, local_steps=3, pretrain_steps=20,
              samples_per_client=200, seed=0, method="fedlora",
              uplink_codec="int8", factored_agg=True)
    legacy = run_pftt(PFTTConfig(engine=False, **kw))
    fused = run_pftt(PFTTConfig(engine=True, **kw))
    np.testing.assert_allclose(legacy["acc_per_round"],
                               fused["acc_per_round"], atol=1e-5)
    np.testing.assert_allclose(legacy["total_bytes"], fused["total_bytes"],
                               rtol=1e-5)
    np.testing.assert_allclose(legacy["mean_round_delay_s"],
                               fused["mean_round_delay_s"], rtol=1e-5)
    np.testing.assert_allclose(legacy["total_energy_j"],
                               fused["total_energy_j"], rtol=1e-5)
    # the codec actually compresses: encoded < raw f32 accounting
    raw = run_pftt(PFTTConfig(engine=True, **dict(kw, uplink_codec="none",
                                                  factored_agg=False)))
    assert fused["total_bytes"] < 0.3 * raw["total_bytes"]


def test_pfit_ppo_codec_engine_matches_loop_including_ledger():
    """build_ppo_round's codec threading (trailing codec_keys arg, masked
    bit charge, decoded-upload masked aggregation) against the legacy
    per-client loop: rewards AND ledger totals agree."""
    from repro.core.pfit import PFITConfig, run_pfit
    kw = dict(n_clients=2, rounds=2, rollout_batch=4, pretrain_steps=15,
              rm_steps=15, d_model=48, n_layers=2, gen_len=8, prompt_len=6,
              seed=0, uplink_codec="int8")
    legacy = run_pfit(PFITConfig(engine=False, **kw))
    fused = run_pfit(PFITConfig(engine=True, **kw))
    np.testing.assert_allclose(legacy["reward_per_round"],
                               fused["reward_per_round"], atol=1e-3)
    np.testing.assert_allclose(legacy["total_bytes"], fused["total_bytes"],
                               rtol=1e-5)
    np.testing.assert_allclose(legacy["total_energy_j"],
                               fused["total_energy_j"], rtol=1e-5)
