"""Unit tests: optimizers, schedules, PPO/GAE, reward models, data,
checkpointing, comm accounting."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.optim import adamw, sgd, clip_by_global_norm, cosine_decay, \
    linear_warmup_cosine


def test_adamw_converges_on_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = trees.tree_add(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_momentum_converges():
    opt = sgd(0.05, momentum=0.9)
    params = {"w": jnp.array([4.0])}
    state = opt.init(params)
    for _ in range(250):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = trees.tree_add(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_by_global_norm():
    t = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(t, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_schedules():
    cd = cosine_decay(1.0, 100)
    assert float(cd(jnp.array(0))) > float(cd(jnp.array(50))) > float(cd(jnp.array(100)))
    wc = linear_warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.array(5))) < float(wc(jnp.array(10)))


def test_adamw_update_mask_skips_paths():
    opt = adamw(0.1, update_mask=lambda p: not p.endswith("/mask"))
    params = {"w": jnp.ones(3), "lora": {"mask": jnp.ones(2)}}
    state = opt.init(params)
    g = {"w": jnp.ones(3), "lora": {"mask": jnp.ones(2)}}
    upd, _ = opt.update(g, state, params)
    assert float(jnp.abs(upd["lora"]["mask"]).sum()) == 0.0
    assert float(jnp.abs(upd["w"]).sum()) > 0.0


def test_gae_matches_manual():
    from repro.rlhf.ppo import gae
    rewards = jnp.array([[0.0, 0.0, 1.0]])
    values = jnp.array([[0.1, 0.2, 0.3]])
    mask = jnp.ones((1, 3))
    adv, ret = gae(rewards, values, mask, gamma=1.0, lam=1.0)
    # manual: delta_t = r + V_{t+1} - V_t ; adv_t = sum of future deltas
    d2 = 1.0 + 0.0 - 0.3
    d1 = 0.0 + 0.3 - 0.2
    d0 = 0.0 + 0.2 - 0.1
    np.testing.assert_allclose(np.asarray(adv[0]),
                               [d0 + d1 + d2, d1 + d2, d2], atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret), np.asarray(adv + values),
                               atol=1e-6)


def test_reward_model_learns_ranking():
    from repro.data.synthetic import InstructionCorpus
    from repro.rlhf.reward_model import RewardModel, train_reward_model
    corpus = InstructionCorpus(seq_len=40, prompt_len=16)
    data = corpus.sample(512, helpful_p=0.5, unsafe_p=0.4)
    rm = RewardModel.create(jax.random.PRNGKey(0), d_model=64, n_layers=1)
    _, stats = train_reward_model(jax.random.PRNGKey(1), rm, data, "safe",
                                  steps=120)
    assert stats["pair_acc"] > 0.8, stats


def test_instruction_corpus_scores():
    from repro.data.synthetic import (InstructionCorpus, helpfulness_score,
                                      safety_score, topic_tokens)
    c = InstructionCorpus(seq_len=48, prompt_len=16)
    s = c.sample(64, helpful_p=1.0, unsafe_p=0.0)
    assert s["help"].mean() > 0.9
    assert (s["safe"] == 1.0).all()
    s = c.sample(64, helpful_p=0.0, unsafe_p=1.0)
    assert s["safe"].mean() < 1.0
    assert safety_score(np.asarray(topic_tokens(0))) == 1.0


def test_checkpoint_roundtrip():
    from repro.checkpoint import load_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, tree)
        out = load_checkpoint(path, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for k, v in trees.flatten(out).items():
        np.testing.assert_allclose(np.asarray(v, np.float32),
                                   np.asarray(trees.flatten(tree)[k], np.float32))


def test_tree_bytes_with_mask():
    from repro.wireless import tree_bytes
    t = {"w": jnp.zeros((10, 10), jnp.float32)}
    assert tree_bytes(t) == 400
    m = {"w": jnp.concatenate([jnp.ones((10, 5)), jnp.zeros((10, 5))], 1)}
    assert tree_bytes(t, nonzero_mask=m) == 200


def test_comm_ledger():
    from repro.wireless import CommLedger, RayleighChannel
    ch = RayleighChannel(mean_snr_db=5.0, seed=0)
    led = CommLedger()
    reports = [ch.uplink(1000) for _ in range(4)]
    led.log_round(reports)
    assert led.total_bytes <= 4000
    assert len(led.rounds) == 1


def test_generate_shapes_and_determinism():
    from repro.configs import get_config
    from repro.models import Model
    from repro.rlhf.rollout import generate
    from repro.sharding import MeshCtx
    cfg = get_config("gpt2-small").reduced()
    m = Model(cfg, meshctx=MeshCtx.single_device())
    params = m.init(jax.random.PRNGKey(0))
    prompts = jnp.ones((2, 8), jnp.int32)
    t1 = generate(m, params, prompts, 8, jax.random.PRNGKey(7))
    t2 = generate(m, params, prompts, 8, jax.random.PRNGKey(7))
    assert t1.shape == (2, 16)
    assert (np.asarray(t1) == np.asarray(t2)).all()
    assert (np.asarray(t1[:, :8]) == np.asarray(prompts)).all()
