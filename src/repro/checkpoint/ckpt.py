"""Flat-key .npz checkpointing for arbitrary pytrees (no orbax offline).

Leaves are stored under their '/'-joined tree paths; restore requires a
template pytree with the same structure (shape/dtype verified).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees


def save_checkpoint(path: str, tree) -> None:
    """Atomic write: serialize to a sibling tmp file, then ``os.replace``.
    A crash mid-write leaves the previous checkpoint intact (readers never
    observe a torn .npz)."""
    flat = trees.flatten(tree)
    arrays = {}
    for k, v in flat.items():
        if v is None:
            continue
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:  # npz can't serialize ml_dtypes
            a = a.astype(np.float32)
        arrays[k] = a
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    final = path if path.endswith(".npz") else path + ".npz"
    tmp = final + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def load_checkpoint(path: str, template):
    """Restore into the structure of ``template``."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def get(p, v):
        if v is None:
            return None
        if p not in data:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = data[p]
        if tuple(arr.shape) != tuple(v.shape):
            raise ValueError(f"shape mismatch at {p}: {arr.shape} vs {v.shape}")
        return jnp.asarray(arr, dtype=v.dtype)

    return trees.map_with_path(get, template)
