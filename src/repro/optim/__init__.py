from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, sgd, clip_by_global_norm, global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant, cosine_decay, linear_warmup_cosine,
)
