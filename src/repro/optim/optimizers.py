"""Optimizers built from scratch (optax is not available offline).

API mirrors the (init, update) convention::

    opt = adamw(lr_schedule, weight_decay=0.01)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = tree_add(params, updates)          # updates already include -lr
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree):
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(lambda a, b: a + b, sq))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


def _as_schedule(lr):
    return lr if callable(lr) else (lambda step: lr)


def adamw(lr, *, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0,
          update_mask: Optional[Callable[[str], bool]] = None) -> Optimizer:
    """AdamW with f32 moments.  ``update_mask(path)`` False → leaf untouched
    (used to keep LoRA enable-masks and frozen leaves out of the step)."""
    lr_fn = _as_schedule(lr)
    from repro import trees

    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": zeros,
                "nu": jax.tree_util.tree_map(jnp.copy, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

        def upd(m, v, p):
            u = -(lr_t * (m * mu_hat_scale
                          / (jnp.sqrt(v * nu_hat_scale) + eps)
                          + weight_decay * p.astype(jnp.float32)))
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        if update_mask is not None:
            updates = trees.map_with_path(
                lambda path, u: u if update_mask(path) else jnp.zeros_like(u),
                updates)
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init=init, update=update)


def sgd(lr, *, momentum: float = 0.0) -> Optimizer:
    lr_fn = _as_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"m": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum == 0.0:
            updates = jax.tree_util.tree_map(
                lambda g, p: (-lr_t * g.astype(jnp.float32)).astype(p.dtype),
                grads, params)
            return updates, {"step": step}
        m = jax.tree_util.tree_map(
            lambda mm, g: momentum * mm + g.astype(jnp.float32),
            state["m"], grads)
        updates = jax.tree_util.tree_map(
            lambda mm, p: (-lr_t * mm).astype(p.dtype), m, params)
        return updates, {"m": m, "step": step}

    return Optimizer(init=init, update=update)
