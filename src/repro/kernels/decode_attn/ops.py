"""Public wrapper: model-layout flash-decode."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attn.kernel import decode_attention_kernel


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     bk: int = 128, interpret: bool = True):
    """q: (B, 1, H, hd); caches: (B, Sc, K, hd); pos scalar → (B, 1, H, hd)."""
    b, _, h, d = q.shape
    _, sc, kh, _ = k_cache.shape
    g = h // kh
    qf = q.transpose(0, 2, 1, 3).reshape(b, kh, g, 1, d).reshape(-1, 1, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(-1, sc, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(-1, sc, d)
    out = decode_attention_kernel(qf, kf, vf, pos, window=window, bk=bk,
                                  interpret=interpret)
    return (out.reshape(b, kh, g, 1, d).reshape(b, h, 1, d)
            .transpose(0, 2, 1, 3))
