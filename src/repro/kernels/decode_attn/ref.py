"""Oracle: masked single-token attention."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k_cache, v_cache, pos, *, window: int = 0):
    bh, _, d = q.shape
    bkv, sc, _ = k_cache.shape
    group = bh // bkv
    k = jnp.repeat(k_cache, group, axis=0)
    v = jnp.repeat(v_cache, group, axis=0)
    s = jnp.einsum("bqd,btd->bqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    kpos = jnp.arange(sc)
    mask = kpos <= pos
    if window > 0:
        mask &= kpos > pos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqt,btd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
