"""Flash-decode Pallas kernel (TPU target): one query token vs a KV cache.

Grid: (batch·q_heads, n_kv_blocks) — the kv dimension iterates sequentially,
carrying online-softmax stats in VMEM scratch.  The current cache length
``pos+1`` arrives as a scalar-prefetch operand so the same compiled kernel
serves every decode step; blocks fully beyond the valid range contribute
nothing (masked), and on real TPU the grid can be truncated per step.

This is the serving hot spot of the PFTT personalized-LLM deployment
(EXPERIMENTS.md §Perf C/D); block shape (bk × head_dim) keeps the working
set ≪ VMEM for every assigned architecture.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bk: int, n_kv_blocks: int, window: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale          # (1, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, bk)

    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = kpos <= pos
    if window > 0:
        mask &= kpos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0]).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, pos, *, window: int = 0,
                            bk: int = 128, interpret: bool = True):
    """q: (BH, 1, d); caches: (BK, Sc, d) with BH = BK·group; pos: scalar
    int32 (cache_len − 1).  Returns (BH, 1, d)."""
    bh, _, d = q.shape
    bkv, sc, _ = k_cache.shape
    group = bh // bkv
    bk = min(bk, sc)
    assert sc % bk == 0
    nk = sc // bk
    scale = d ** -0.5

    kernel = functools.partial(_kernel, scale=scale, bk=bk, n_kv_blocks=nk,
                               window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, j, pos_ref: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, pos_ref, g=group:
                         (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, pos_ref, g=group:
                         (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, j, pos_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32)[None], q, k_cache, v_cache)
