"""Oracle: two-matmul LoRA."""
import jax.numpy as jnp


def lora_ref(x, w, a, b, *, scale: float):
    base = x.astype(jnp.float32) @ w.astype(jnp.float32)
    lora = (x.astype(jnp.float32) @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    return (base + scale * lora).astype(x.dtype)
