"""Public wrapper for the fused LoRA projection (PFTT serving hot path).

This is the serving lowering of the factored LoRA contract: model code
reaches it through ``peft.lora_proj(..., backend="pallas")`` (threaded via
``Model.*(opts={"lora_backend": "pallas"})``), computing the unmerged form
``x·W + scale·(x·A)·B`` in one fused pass.  Forward-only — ``pallas_call``
has no VJP here, so training keeps the jnp factored path; the kernel picks
compatible block sizes for the model's real (non-128-aligned) projection
shapes."""
from __future__ import annotations

import functools

import jax

from repro.kernels.lora_fused.kernel import lora_fused_kernel


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def lora_matmul(x, w, a, b, *, scale: float, interpret: bool = True):
    """x: (..., K) @ [W (K,N) + scale·A(K,r)·B(r,N)] → (..., N)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    xf = x.reshape(-1, k)
    out = lora_fused_kernel(xf, w, a, b, scale=scale, interpret=interpret)
    return out.reshape(*lead, w.shape[1])
