"""Fused LoRA matmul Pallas kernel: y = x·W + (α/r)·(x·A)·B in one pass.

PFTT serves *unmerged* personalized models (base W stays shared across
clients; each client's LoRA is tiny).  Fusing the low-rank path into the
base GEMM avoids a second read of x from HBM and keeps the (bm × r)
intermediate in VMEM — the arithmetic intensity of the LoRA path alone is
far below the TPU ridge point, so unfused it is pure memory traffic.

Grid: (M/bm, N/bn, K/bk); accumulators for both the base tile and the x·A
tile live in VMEM scratch across the K iteration; the rank-r correction is
applied on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scale: float, n_k: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jax.lax.dot(x, w_ref[...],
                                preferred_element_type=jnp.float32)
    xa_ref[...] += jax.lax.dot(x, a_ref[...],
                               preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _finalize():
        lora = jax.lax.dot(xa_ref[...].astype(b_ref.dtype), b_ref[...],
                           preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


def _block(dim: int, pref: int) -> int:
    """Largest usable block ≤ pref that tiles ``dim`` exactly; falls back to
    the whole dim (fine in interpret mode / small models) so the kernel
    accepts the model's real projection shapes, not only 128-multiples."""
    if dim <= pref:
        return dim
    if dim % pref == 0:
        return pref
    for cand in range(pref, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def lora_fused_kernel(x, w, a, b, *, scale: float, bm: int = 128,
                      bn: int = 128, bk: int = 128, interpret: bool = True):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) → (M, N)."""
    m, k = x.shape
    _, n = w.shape
    r = a.shape[1]
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    nm, nn, nk = m // bm, n // bn, k // bk

    kernel = functools.partial(_kernel, scale=scale, n_k=nk)
    return pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
