"""Mamba-2 SSD chunked-scan Pallas kernel (TPU target).

Grid: (B·H, n_chunks).  The chunk dimension iterates sequentially per (b,h),
carrying the SSM state (P×N) in VMEM scratch — the inter-chunk recurrence
lives *inside* the kernel, so a layer's whole scan is one pallas_call.  The
intra-chunk term is the masked (L×L)·(L×P) GEMM pair the MXU wants; chunk
length L=128…256 keeps q/k-like operands and the state in VMEM.

Inputs are pre-projected (x, dt, B, C per head); gating/conv/projections
stay in XLA (they are plain GEMMs it already fuses well).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hlast_ref, state_ref,
            *, chunk: int, n_chunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, 1) → (L,)
    dt = dt[:, 0]
    a_coef = a_ref[0, 0]                      # scalar
    bmat = b_ref[0].astype(jnp.float32)       # (L, N)
    cmat = c_ref[0].astype(jnp.float32)       # (L, N)

    ad = dt * a_coef                          # (L,)
    cs = jnp.cumsum(ad)                       # (L,)
    # intra-chunk decay matrix: exp(cs_i - cs_j) for i >= j else 0
    diff = cs[:, None] - cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(li >= lj, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))
    scores = scores * decay * dt[None, :]
    y_intra = jax.lax.dot(scores, x)          # (L, P)

    state = state_ref[...]                    # (P, N)
    y_inter = jax.lax.dot(cmat * jnp.exp(cs)[:, None], state.T)  # (L, P)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    total = cs[-1]
    decay_out = jnp.exp(total - cs)           # (L,)
    contrib = bmat * (dt * decay_out)[:, None]          # (L, N)
    state_ref[...] = (jnp.exp(total) * state
                      + jax.lax.dot(x.T, contrib))      # (P, N)

    @pl.when(j == n_chunks - 1)
    def _emit_state():
        hlast_ref[0] = state_ref[...]


def ssd_chunk_kernel(x, dt, a_coef, bmat, cmat, *, chunk: int,
                     interpret: bool = True):
    """x: (BH, S, P); dt: (BH, S); a_coef: (BH,); b/c: (BH, S, N)
    → (y (BH, S, P), h_final (BH, P, N))."""
    bh, s, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, hlast = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, p, n), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], a_coef[:, None], bmat, cmat)
    return y, hlast
