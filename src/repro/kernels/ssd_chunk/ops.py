"""Public wrapper: model-layout SSD scan via the Pallas chunk kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_coef, bmat, cmat, *, chunk: int = 256,
             interpret: bool = True):
    """Model layout: x (B,S,H,P); dt (B,S,H); a_coef (H,); b/c (B,S,H,N)
    → (y (B,S,H,P), h_final (B,H,P,N))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    af = jnp.tile(a_coef, b)
    bf = bmat.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    cf = cmat.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    y, hf = ssd_chunk_kernel(xf, dtf, af, bf, cf, chunk=chunk,
                             interpret=interpret)
    return (y.reshape(b, h, s, p).transpose(0, 2, 1, 3),
            hf.reshape(b, h, p, n))
