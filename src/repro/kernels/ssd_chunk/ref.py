"""Oracle for the SSD chunk kernel: naive O(S·N·P) recurrent scan."""
import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a_coef, bmat, cmat):
    """x: (BH, S, P); dt: (BH, S); a_coef: (BH,); b/c: (BH, S, N)
    → (y, h_final) computed token-by-token."""
    def per_seq(x1, dt1, a1, b1, c1):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(dtt * a1) * h + dtt * jnp.outer(xt, bt)   # (P, N)
            y = h @ ct
            return h, y
        h0 = jnp.zeros((x1.shape[-1], b1.shape[-1]), jnp.float32)
        h, y = jax.lax.scan(step, h0, (x1.astype(jnp.float32),
                                       dt1.astype(jnp.float32),
                                       b1.astype(jnp.float32),
                                       c1.astype(jnp.float32)))
        return y, h
    y, h = jax.vmap(per_seq)(x, dt, a_coef, bmat, cmat)
    return y.astype(x.dtype), h
