"""Block-sparse attention Pallas kernel — the paper's sparse-attention
device as a TPU kernel.

The static sparsity pattern (sink blocks + local band + strided global
blocks, see ``repro.models.attention.sparse_block_table``) is passed as a
scalar-prefetch operand: the grid's last dimension enumerates only the
ACTIVE kv blocks per q block (A ≪ n_kv_blocks), and the kv BlockSpec index
map reads the actual block id from the prefetched table.  Compute and HBM
traffic are therefore O(S·A·block) — genuinely sub-quadratic, matching the
gather-based jnp lowering.

Invalid table slots point at block 0 with a mask that voids their
contribution (positions > qpos are masked anyway for the causal diagonal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(idx_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, bq: int, bk: int,
            n_active: int):
    i = pl.program_id(1)
    a = pl.program_id(2)

    @pl.when(a == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))

    blk = idx_ref[i, a]
    ok = valid_ref[i, a]
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = blk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (kpos <= qpos) & (ok > 0)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0]).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(a == n_active - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def block_sparse_attention_kernel(q, k, v, idx, valid, *, block: int,
                                  interpret: bool = True):
    """q: (BH, Sq, d); k/v: (BK, Sk, d); idx/valid: (n_q_blocks, A) static
    tables from ``sparse_block_table``.  Returns (BH, Sq, d)."""
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    assert sq % block == 0 and sk % block == 0
    nq = sq // block
    n_active = idx.shape[1]
    scale = d ** -0.5

    kernel = functools.partial(_kernel, scale=scale, bq=block, bk=block,
                               n_active=n_active)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bh, nq, n_active),
        in_specs=[
            pl.BlockSpec((1, block, d),
                         lambda b, i, a, idx_ref, valid_ref: (b, i, 0)),
            pl.BlockSpec((1, block, d),
                         lambda b, i, a, idx_ref, valid_ref, g=group:
                         (b // g, idx_ref[i, a], 0)),
            pl.BlockSpec((1, block, d),
                         lambda b, i, a, idx_ref, valid_ref, g=group:
                         (b // g, idx_ref[i, a], 0)),
        ],
        out_specs=pl.BlockSpec((1, block, d),
                               lambda b, i, a, idx_ref, valid_ref: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block, 1), jnp.float32),
            pltpu.VMEM((block, 1), jnp.float32),
            pltpu.VMEM((block, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(idx, valid, q, k, v)
