"""Public wrapper for the block-sparse attention kernel — model layout,
pattern table construction from the config."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import SparseAttnConfig
from repro.kernels.block_sparse_attn.kernel import block_sparse_attention_kernel
from repro.models.attention import sparse_block_table


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def block_sparse_attention(q, k, v, cfg: SparseAttnConfig, *,
                           interpret: bool = True):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) → (B, Sq, H, hd)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    bs = cfg.block_size
    idx_np, valid_np = sparse_block_table(sq // bs, sk // bs, cfg)
    idx = jnp.asarray(idx_np)
    valid = jnp.asarray(valid_np.astype(jnp.int32))
    qf = q.transpose(0, 2, 1, 3).reshape(b, kh, g, sq, d).reshape(-1, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(-1, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(-1, sk, d)
    out = block_sparse_attention_kernel(qf, kf, vf, idx, valid, block=bs,
                                        interpret=interpret)
    return (out.reshape(b, kh, g, sq, d).reshape(b, h, sq, d)
            .transpose(0, 2, 1, 3))
