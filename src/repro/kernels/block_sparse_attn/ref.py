"""Oracle: dense attention restricted to the static block-sparse mask."""
import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def block_sparse_ref(q, k, v, idx, valid, *, block: int):
    """Same contract as the kernel; mask materialized densely."""
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    nq, nk = sq // block, sk // block
    mask = np.zeros((sq, sk), bool)
    idx = np.asarray(idx)
    valid = np.asarray(valid)
    for i in range(nq):
        for a in range(idx.shape[1]):
            if valid[i, a]:
                j = int(idx[i, a])
                mask[i * block:(i + 1) * block,
                     j * block:(j + 1) * block] = True
    mask &= np.tril(np.ones((sq, sk), bool))
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    s = jnp.where(jnp.asarray(mask)[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
