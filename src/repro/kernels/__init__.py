"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package has:
* ``kernel.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
* ``ops.py``    — jit'd public wrapper (layout handling, GQA broadcast, ...)
* ``ref.py``    — pure-jnp oracle used by the allclose sweep tests

On this CPU container kernels are validated with ``interpret=True``; the
model code lowers through the jnp paths (``repro.models.attention`` etc.),
with the ops-level ``use_pallas`` flag selecting the kernels on real TPU.
"""
