"""Pure-jnp oracle for the flash attention kernel."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (BH, Sq, d); k/v: (BK, Sk, d), BH = BK·group → (BH, Sq, d)."""
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
