"""Flash attention Pallas kernel (TPU target).

Grid: (batch·q_heads, n_q_blocks, n_kv_blocks) — the kv dimension iterates
sequentially per (bh, i), carrying the online-softmax state (m, l, acc) in
VMEM scratch.  GQA is handled in the index map: the kv operand is indexed by
``bh // group`` so grouped heads share kv blocks without materializing a
broadcast.  Causal masking is positional; on real TPU the diagonal-block
skip (j > i never contributes) is a grid-size optimization — see
EXPERIMENTS.md §Perf.

Block shapes default to (128, head_dim): 128 is the MXU tile edge, and the
working set per step (q, k, v blocks + acc) stays ≪ 16 MiB VMEM for all
head_dims used by the assigned architectures (64…256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_kv_blocks: int):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0]).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q: (BH, Sq, d); k/v: (BK, Sk, d) with BH = BK·group.
    Returns (BH, Sq, d)."""
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    scale = d ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk,
        n_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
