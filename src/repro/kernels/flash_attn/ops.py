"""Public wrapper: model-layout (B,S,H,hd) GQA attention via the Pallas
flash kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) → (B, Sq, H, hd).

    Flattens (batch, head) into the kernel's leading grid dim; GQA sharing is
    resolved inside the kernel's kv index map (no broadcast materialized)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    # (B, S, H, d) → (B, K, G, S, d) → (B·K·G, S, d): head-major so that
    # bh // g indexes the right kv head
    qf = q.transpose(0, 2, 1, 3).reshape(b, kh, g, sq, d).reshape(-1, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(-1, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(-1, sk, d)
    out = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=interpret)
    return (out.reshape(b, kh, g, sq, d).reshape(b, h, sq, d)
            .transpose(0, 2, 1, 3))
