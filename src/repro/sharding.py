"""Mesh context + divisibility-aware sharding policy.

``MeshCtx`` carries the mesh and logical axis names through the model code
(the MoE layer runs a ``shard_map`` over it; the launcher builds param/batch
shardings from it).  The policy is rule-based: a tensor dim is sharded on an
axis only when divisible by the axis size, otherwise it is replicated — this
is what lets one config system drive 10 architectures × 4 shapes × 2 meshes
without per-case hand-tuning.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    """``jax.shard_map`` compat shim: on jax ≥ 0.6 forwards directly; on
    0.4.x (this container) routes to ``jax.experimental.shard_map`` with
    ``check_vma`` mapped to its older ``check_rep`` spelling.  Model code
    must use THIS instead of ``jax.shard_map``."""
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def use_mesh(mesh: Mesh):
    """``jax.set_mesh`` compat: a context manager activating ``mesh`` (on
    0.4.x the Mesh object itself is the context manager)."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


# ---------------------------------------------------------------------------
# Cohort (client-axis) sharding — the federated engine's device layout
# ---------------------------------------------------------------------------


def client_shard_axes(mesh: Mesh, client_axes=None) -> Tuple[str, ...]:
    """Mesh axes the stacked client dim shards over: explicit ``client_axes``
    if given, else every non-"model" axis (("pod","data") on the production
    mesh, ("data",) on a flat one) — tensor parallelism stays orthogonal."""
    if client_axes is not None:
        return tuple(client_axes)
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes or tuple(mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class CohortSharding:
    """Device layout for one stacked federated cohort.

    The leading client axis of every stacked leaf is sharded over
    ``axes``; cohorts whose size does not divide the shard count are
    padded with **ghost clients** — copies of client 0 that train
    normally but carry aggregation weight 0, so the weighted-mean /
    masked-mean math (and its all-outage gate) excludes them exactly
    (copies, not zeros: a ghost's forward must be as numerically
    well-behaved as a real client's, since NaN·0 = NaN would poison the
    psum).  Everything without a client axis (frozen base, global model)
    stays replicated."""

    mesh: Mesh
    axes: Tuple[str, ...]
    n_clients: int       # real cohort size
    total: int           # ghost-padded size (multiple of n_shards)

    @property
    def n_shards(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    @property
    def n_pad(self) -> int:
        return self.total - self.n_clients

    @property
    def named(self) -> NamedSharding:
        """Client-axis sharding (prefix spec: dim 0 over ``axes``)."""
        return NamedSharding(self.mesh, P(self.axes))

    @property
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def pad(self, per_client: Sequence) -> list:
        """[n_clients] list → [total] list, ghosts = copies of entry 0."""
        per_client = list(per_client)
        assert len(per_client) == self.n_clients, (len(per_client),
                                                   self.n_clients)
        return per_client + [per_client[0]] * self.n_pad

    def pad_vec(self, values, fill: float = 0.0) -> np.ndarray:
        """Append ``fill`` entries for every ghost client (fault masks pad
        with 1.0 so ghosts keep training/receiving like the sync engine)."""
        v = np.asarray(values, np.float32)
        return np.concatenate([v, np.full((self.n_pad,), fill, np.float32)])

    def pad_weights(self, weights) -> np.ndarray:
        """Append zero aggregation weight for every ghost client."""
        return self.pad_vec(weights, 0.0)


def cohort_sharding(mesh: Mesh, n_clients: int,
                    client_axes=None) -> CohortSharding:
    axes = client_shard_axes(mesh, client_axes)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    total = -(-n_clients // n_shards) * n_shards
    return CohortSharding(mesh=mesh, axes=axes, n_clients=n_clients,
                          total=total)


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    batch_axes: Tuple[str, ...] = ("data",)   # ("pod","data") on multi-pod
    model_axis: str = "model"

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return self.batch_axes + (self.model_axis,)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    @classmethod
    def single_device(cls) -> "MeshCtx":
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return cls(mesh=mesh)

    # -- divisibility-aware spec construction --------------------------------
    def dim_axis(self, size: int, axis) -> Optional[object]:
        """Return ``axis`` (a name or tuple of names) if ``size`` is divisible
        by its total extent, else None (replicate)."""
        if axis is None:
            return None
        names = axis if isinstance(axis, tuple) else (axis,)
        extent = int(np.prod([self.mesh.shape[a] for a in names]))
        if extent <= 1:
            return None
        return axis if size % extent == 0 else None

    def spec(self, shape: Sequence[int], axes: Sequence[object]) -> P:
        """Build a PartitionSpec, dropping any axis that doesn't divide."""
        assert len(shape) == len(axes), (shape, axes)
        return P(*[self.dim_axis(s, a) for s, a in zip(shape, axes)])


def local_batch(meshctx: MeshCtx, global_batch: int) -> int:
    d = meshctx.data_size
    return max(1, math.ceil(global_batch / d))


# ---------------------------------------------------------------------------
# Parameter / batch / cache sharding rules
# ---------------------------------------------------------------------------
#
# Rules are (path-suffix regex → per-dim logical axes); meshctx.spec() then
# drops any axis that does not divide the dim.  "model" below is the logical
# tensor-parallel axis; batch dims use meshctx.batch_axes (("pod","data") on
# the multi-pod mesh).  Unmatched leaves replicate.

import re as _re

_M = "model"
_F = "__fsdp__"   # sentinel → meshctx.batch_axes (ZeRO/FSDP-style sharding
                  # of weights + optimizer moments over the data axes)

# (regex, axes-per-dim counted from the LAST dim backwards).  Standard
# 2-D layout: contracting/row dim over FSDP, output/col dim over model
# (column-parallel) or vice versa (row-parallel).  The divisibility guard in
# meshctx.spec() silently drops axes that don't divide (e.g. whisper's odd
# 51865 vocab replicates over model but still FSDP-shards d_model).
_PARAM_RULES = [
    (r"embed$", (_M, _F)),
    (r"lm_head$", (_F, _M)),
    (r"pos_embed$", (_M, _F)),
    (r"enc_pos$", (None, None)),
    (r"projector$", (_F, _M)),
    (r"(mixer|cross)/w[qkv]$", (_F, _M)),
    (r"(mixer|cross)/wo$", (_M, _F)),
    (r"mixer/wq_a$", (_F, _M)),
    (r"mixer/wq_b$", (_F, _M)),
    (r"mixer/wkv_a$", (_F, _M)),
    (r"mixer/wkv_b$", (_F, _M)),
    (r"mixer/in_proj$", (_F, _M)),
    (r"mixer/out_proj$", (_M, _F)),
    (r"mixer/conv_w$", (None, _M)),
    (r"mixer/conv_b$", (_M,)),
    (r"mixer/gate_norm/scale$", (_M,)),
    (r"ff/wg$", (_F, _M)),
    (r"ff/wu$", (_F, _M)),
    (r"ff/wd$", (_M, _F)),
    (r"ff/shared/w[gu]$", (_F, _M)),
    (r"ff/shared/wd$", (_M, _F)),
    (r"ff/router$", (None, None)),
    (r"adapter/w[du]$", (None, None)),
]

# MoE expert slabs (…, E, d, f): experts over model, d over FSDP
_EXPERT_RULES = [
    (r"ff/wg$", (_M, _F, None)),
    (r"ff/wu$", (_M, _F, None)),
    (r"ff/wd$", (_M, None, _F)),
]


def param_specs(meshctx: MeshCtx, params_shapes, cfg=None,
                policy: str = "fsdp"):
    """Build a PartitionSpec tree for a params(-shaped) tree.

    ``cfg`` (ModelConfig) identifies which stage/pattern positions are MoE —
    their ff weights are expert slabs (E, d, f) sharded over experts; dense
    ff weights are sharded column/row-parallel instead.

    ``policy`` (§Perf sharding experiments):
      * ``fsdp``              — weights+moments sharded over (data × model)
                                (ZeRO-3-style; baseline)
      * ``fsdp_experts_only`` — FSDP only on expert slabs (the bulk of MoE
                                params); everything else pure TP — removes
                                the per-layer dense-weight all-gathers
      * ``tp``                — pure tensor parallelism (memory-permitting)
      * ``dp``                — pure data parallelism: weights replicated,
                                batch sharded over ALL axes — the right
                                layout for small models (whisper) that a
                                16-way model axis only slows down
    """
    from repro import trees as _trees

    moe_positions = set()
    if cfg is not None:
        for si, stage in enumerate(cfg.stages):
            for pi, kind in enumerate(stage.pattern):
                if kind.ff == "moe":
                    moe_positions.add(f"stages/{si}/layers/{pi}/ff/")

    def resolve(ax, is_expert=False):
        if policy == "dp":
            return None
        if ax == _F:
            if policy == "tp":
                return None
            if policy == "fsdp_experts_only" and not is_expert:
                return None
            return meshctx.batch_axes
        return ax

    def leaf_spec(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        is_moe = any(path.startswith(p) for p in moe_positions)
        if is_moe and not _re.search(r"/(router|shared/w[gud])$", path):
            for pat, axes in _EXPERT_RULES:
                if _re.search(pat, path):
                    # axes aligned to the LAST 3 dims: (R?, E, d, f)
                    full = (None,) * (len(shape) - 3) + tuple(
                        resolve(a, is_expert=True) for a in axes)
                    return meshctx.spec(shape, full)
        for pat, axes in _PARAM_RULES:
            if _re.search(pat, path):
                n = len(axes)
                if len(shape) < n:
                    return P(*([None] * len(shape)))
                full = (None,) * (len(shape) - n) + tuple(
                    resolve(a) for a in axes)
                return meshctx.spec(shape, full)
        return P(*([None] * len(shape)))

    return _trees.map_with_path(leaf_spec, params_shapes)


def batch_specs(meshctx: MeshCtx, batch_shapes):
    """Batch dims shard over the data axes; everything else replicated."""
    from repro import trees as _trees

    def leaf_spec(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        return meshctx.spec(shape, (meshctx.batch_axes,)
                            + (None,) * (len(shape) - 1))

    return _trees.map_with_path(leaf_spec, batch_shapes)


def cache_specs(meshctx: MeshCtx, cache_shapes, *, batch: int):
    """Decode-cache sharding: batch over data axes when divisible; the cache
    sequence dim over the model axis (flash-decode style partial softmax) —
    and over (data+model) when batch cannot shard (long_500k, B=1).
    Mamba states shard heads/feature dims over model."""
    from repro import trees as _trees

    batch_ok = batch % max(meshctx.data_size, 1) == 0 and meshctx.data_size > 1
    seq_axes = _M if batch_ok else tuple(meshctx.batch_axes) + (_M,)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        b_ax = meshctx.batch_axes if batch_ok else None
        if path.endswith(("/k", "/v", "/xk", "/xv", "/k_pers", "/v_pers")):
            # (R, B, S, K, hd)
            return meshctx.spec(shape, (None, b_ax, seq_axes, None, None))
        if path.endswith(("/k_ring", "/v_ring")):
            return meshctx.spec(shape, (None, b_ax, None, None, None))
        if path.endswith(("/ckv", "/kpe")):
            return meshctx.spec(shape, (None, b_ax, seq_axes, None))
        if path.endswith("/h"):       # (R, B, H, P, N)
            return meshctx.spec(shape, (None, b_ax, _M, None, None))
        if path.endswith("/conv"):    # (R, B, W-1, conv_dim)
            return meshctx.spec(shape, (None, b_ax, None, _M))
        return P(*([None] * len(shape)))

    return _trees.map_with_path(leaf_spec, cache_shapes)


def with_specs(shapes_tree, specs_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (for .lower())."""
    import jax as _jax

    def attach(sds, spec):
        return _jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                     sharding=NamedSharding(mesh, spec))

    return _jax.tree_util.tree_map(attach, shapes_tree, specs_tree,
                                   is_leaf=lambda x: isinstance(
                                       x, _jax.ShapeDtypeStruct))
