"""PFIT — Personalized Federated Instruction Tuning (paper §IV-C).

Each client fine-tunes the *last K layers* of a shared policy with PPO
against a personalized reward: a client-specific linear combination of the
helpfulness and safety reward models, plus the negative-L2 regularization
toward the global model.  A head-structured sparsity mask (the paper's
"sparse attention update", 40 %) reduces both trainable attention parameters
and upload bytes.  The server aggregates only the unfrozen masked layers
(``masked_fedavg``).

Fig. 4 baselines as method variants:
* ``sfl``      — single reward model (helpfulness only), 20 % sparsity
* ``pfl``      — personalized double reward, NO sparsity
* ``shepherd`` — federated LoRA instruction tuning (supervised, no RLHF) [4]

Execution goes through the vmapped cohort engine (``core/cohort.py``): the
whole round — vmapped PPO (rollout, double reward, clipped updates under
per-client gradient masks), masked stacked aggregation with the outage
weight vector, and the masked broadcast-back — is ONE jitted program.
``PFITConfig(engine=False)`` keeps the legacy per-client loop (parity
oracle + benchmark baseline).

The shepherd baseline executes its LoRA FACTORED (``peft.lora_proj``):
training threads the rank-r factors next to the frozen global (unbatched
under the client-vmap) and eval generation serves the personalized LoRA
unmerged through prefill + decode.  ``PFITConfig(factored=False)`` keeps
the merged oracle.

``run_pfit(cfg, mesh=...)`` shards the fused round over the device mesh
(``shard_map`` on the stacked client axis, masked aggregation as psums,
global model + reward models replicated, ghost-padded cohorts) — the same
pathway as ``run_pftt``; see ``core/cohort.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.comms import ChannelBudget, get_codec
from repro.comms import codec as codec_mod
from repro.configs import get_config
from repro.core.aggregation import (factored_fedavg_stacked, fedavg,
                                    fedavg_stacked, masked_fedavg,
                                    masked_fedavg_stacked)
from repro.core.cohort import (HostBatchStacker, build_cohort_eval,
                               build_ppo_round, build_supervised_round)
from repro.core.robust import StalenessConfig, StalenessTracker
from repro.core.rewards import ClientPreference, DoubleReward
from repro.data.partition import client_topic_preferences
from repro.data.synthetic import InstructionCorpus, N_TOPICS
from repro.models import Model
from repro.models import peft as peft_mod
from repro.obs.metrics import RunTelemetry
from repro.obs.trace import SpanTracer, jax_profile_start, jax_profile_stop
from repro.optim import adamw
from repro.rlhf.ppo import PPOConfig, PPOTrainer
from repro.rlhf.reward_model import RewardModel, train_reward_model
from repro.rlhf.rollout import generate
from repro.sharding import MeshCtx, cohort_sharding
from repro.wireless import (ArrivalModel, CommLedger, DeadlineConfig,
                            FaultPlan, RayleighChannel, tree_bytes)

METHODS = ("pfit", "sfl", "pfl", "shepherd")


@dataclasses.dataclass(frozen=True)
class PFITConfig:
    method: str = "pfit"
    n_clients: int = 4
    rounds: int = 20
    rollout_batch: int = 16
    prompt_len: int = 16
    gen_len: int = 24
    last_k: int = 2
    sparsity: float = 0.4          # pfit 0.4 | sfl 0.2 | pfl 0.0
    d_model: int = 128
    n_layers: int = 4
    lr: float = 4e-4
    pretrain_steps: int = 300
    pretrain_lr: float = 1e-3
    rm_steps: int = 250
    lambda_reg: float = 1e-5
    shepherd_steps: int = 10       # supervised LoRA steps per round
    lora_rank: int = 8
    snr_db: float = 5.0
    seed: int = 0
    verbose: bool = False
    engine: bool = True            # fused vmapped round step (cohort engine)
    factored: bool = True          # unmerged LoRA execution for shepherd
                                   # train/serve (False → merged oracle)
    uplink_codec: str = "none"     # lossy upload compression (repro.comms)
    factored_agg: bool = False     # shepherd: SVD re-projection aggregation
                                   # of LoRA factor pairs (no densification)
    tx_power_w: float = 0.5        # uplink transmit power (energy charge)
    fault_plan: Optional[object] = None   # wireless.faults.FaultPlan —
                                   # straggler-tolerant robust round (the
                                   # zero plan is bitwise the sync engine)
    staleness_alpha: float = 1.0   # FedAsync α (cancels under normalization)
    staleness_a: float = 0.0       # staleness exponent a in α·(1+s)^(-a)
    max_staleness: int = 0         # pending payloads older than this drop;
                                   # 0 = sync drop-on-failure semantics
    deadline: Optional[DeadlineConfig] = None  # continuous-time round
                                   # (wireless/arrivals.py); inert/None is
                                   # bitwise the round-granular runtime
    ppo: PPOConfig = PPOConfig()
    population: Optional[object] = None  # fl.population.PopulationConfig —
                                   # sampled-cohort population mode
                                   # (shepherd only; PPO methods carry full
                                   # per-client params, which don't fit the
                                   # KB-per-client population regime)
    telemetry: Optional[object] = None  # repro.obs.TelemetryConfig — JSONL
                                   # round events + span tracing; health
                                   # scalars ride the supervised (shepherd)
                                   # body only (the PPO body is a follow-on)


def _method_settings(cfg: PFITConfig):
    if cfg.method == "pfit":
        return dict(sparsity=cfg.sparsity, double=True)
    if cfg.method == "sfl":
        return dict(sparsity=0.2, double=False)
    if cfg.method == "pfl":
        return dict(sparsity=0.0, double=True)
    if cfg.method == "shepherd":
        return dict(sparsity=0.0, double=False)
    raise ValueError(cfg.method)


def _pretrain_policy(key, model, params, corpus, steps, lr, batch, verbose):
    """Standard LM pre-training on the instruction corpus so generation is
    topical before RL starts (the 'pre-trained LLM' of Step 1)."""
    opt = adamw(lr)
    st = opt.init(params)
    rng = np.random.RandomState(7)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step_fn(params, st, batch_d):
        def loss_fn(p):
            return model.lm_loss(p, batch_d)
        loss, g = jax.value_and_grad(loss_fn)(params)
        upd, st = opt.update(g, st, params)
        return trees.tree_add(params, upd), st, loss

    for i in range(steps):
        s = corpus.sample(batch, helpful_p=0.6, unsafe_p=0.3, rng=rng)
        toks = jnp.asarray(s["tokens"])
        batch_d = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                   "mask": jnp.asarray(s["mask"][:, 1:])}
        params, st, loss = step_fn(params, st, batch_d)
    if verbose:
        print(f"[pfit] policy pretrain loss {float(loss):.3f}")
    return params


def run_pfit(cfg: PFITConfig, mesh=None, client_axes=None) -> Dict:
    """``mesh`` (optional ``jax.sharding.Mesh``): shard the fused cohort
    round across it (engine path only) — see the module docstring.
    ``cfg.population`` switches to sampled-cohort population mode
    (shepherd only)."""
    assert cfg.method in METHODS
    if cfg.population is not None:
        return _run_pfit_population(cfg, mesh, client_axes)
    ms = _method_settings(cfg)
    key = jax.random.PRNGKey(cfg.seed)
    rng = np.random.RandomState(cfg.seed)
    meshctx = MeshCtx.single_device()

    # ---- policy: reduced GPT-2 (paper's local LLM)
    mcfg = get_config("gpt2-small").reduced(d_model=cfg.d_model,
                                            repeats=cfg.n_layers)
    model = Model(mcfg, meshctx=meshctx)
    corpus = InstructionCorpus(seq_len=cfg.prompt_len + cfg.gen_len,
                               prompt_len=cfg.prompt_len, seed=cfg.seed)
    params = model.init(key)
    params = _pretrain_policy(key, model, params, corpus, cfg.pretrain_steps,
                              cfg.pretrain_lr, 16, cfg.verbose)
    params["value_head"] = jnp.zeros((mcfg.d_model, 1), jnp.float32)

    # ---- double reward models (helpfulness + safety), BT-trained
    rm_data = corpus.sample(1024, helpful_p=0.5, unsafe_p=0.4, rng=rng)
    rm_h = RewardModel.create(jax.random.fold_in(key, 11))
    rm_h_params, rmh_stats = train_reward_model(
        key, rm_h, rm_data, "help", steps=cfg.rm_steps)
    rm_s = RewardModel.create(jax.random.fold_in(key, 12))
    rm_s_params, rms_stats = train_reward_model(
        key, rm_s, rm_data, "safe", steps=cfg.rm_steps)
    double = DoubleReward(rm_h, rm_h_params, rm_s, rm_s_params)
    if cfg.verbose:
        print(f"[pfit] rm pair-acc help={rmh_stats['pair_acc']:.3f} "
              f"safe={rms_stats['pair_acc']:.3f}")

    # ---- clients: diverse (α_help, α_safe) preferences + topic skew
    topic_prefs = client_topic_preferences(cfg.n_clients, N_TOPICS, 0.3,
                                           seed=cfg.seed)
    prefs = []
    for ci in range(cfg.n_clients):
        a = ci / max(cfg.n_clients - 1, 1)       # 0 … 1
        if ms["double"]:
            prefs.append(ClientPreference(alpha_help=0.25 + 0.5 * a,
                                          alpha_safe=0.75 - 0.5 * a,
                                          lambda_reg=cfg.lambda_reg))
        else:  # single (helpfulness-only) reward model
            prefs.append(ClientPreference(alpha_help=1.0, alpha_safe=0.0,
                                          lambda_reg=cfg.lambda_reg))

    # ---- trainable masks: last-K layers × head sparsity (paper Step 1)
    lastk_mask = peft_mod.last_k_layers_mask(params, mcfg, cfg.last_k)
    client_masks = [
        jax.tree_util.tree_map(
            lambda a, b: a * b, lastk_mask,
            peft_mod.head_sparsity_mask(params, mcfg, ms["sparsity"],
                                        seed=cfg.seed + ci))
        for ci in range(cfg.n_clients)]

    opt = adamw(cfg.lr)
    peft_cfg = peft_mod.PEFTConfig(lora_rank=cfg.lora_rank,
                                   lora_targets=("mixer/wq", "mixer/wv"))
    clients: List[Dict] = []
    for ci in range(cfg.n_clients):
        state = {"params": params, "opt_state": opt.init(params)}
        if cfg.method == "shepherd":
            lora = peft_mod.init_lora(jax.random.fold_in(key, 200 + ci),
                                      params, peft_cfg)
            state = {"lora": lora, "opt_state": opt.init(lora)}
        clients.append(state)
    global_params = params

    # ---- shepherd supervised step (unjitted; legacy path jits it, the
    # cohort engine vmaps it).  Factored: the frozen global stays unbatched
    # under the engine's client-vmap, only rank-r factors carry the client
    # axis; merged oracle behind cfg.factored=False.
    lscale = peft_mod.lora_scale(peft_cfg)

    def shepherd_local_step(lora, opt_state, batch):
        def loss_fn(lo):
            if cfg.factored:
                return model.lm_loss(global_params, batch, lora=lo,
                                     lora_scale=lscale)
            eff = peft_mod.apply_lora(global_params, lo, peft_cfg)
            return model.lm_loss(eff, batch)
        loss, g = jax.value_and_grad(loss_fn)(lora)
        upd, opt_state = opt.update(g, opt_state, lora)
        return trees.tree_add(lora, upd), opt_state, loss

    shepherd_step = jax.jit(shepherd_local_step)

    channel = RayleighChannel(mean_snr_db=cfg.snr_db, seed=cfg.seed)
    budget = ChannelBudget(channel, tx_power_w=cfg.tx_power_w)
    ledger = CommLedger()
    reward_curve = []

    # ---- observability (repro.obs): health scalars ride the supervised
    # (shepherd) fused body only — the PPO body is a documented follow-on
    tele_cfg = cfg.telemetry
    tracer = SpanTracer(enabled=bool(tele_cfg and tele_cfg.trace))
    tele = RunTelemetry(tele_cfg.out_dir if tele_cfg else None, tracer=tracer)
    health = (bool(tele_cfg and tele_cfg.health) and cfg.engine
              and cfg.method == "shepherd")

    # ---- straggler-tolerant runtime: one fault trace + staleness tracker
    # shared by the engine and the legacy loop (core/robust.py)
    dl = cfg.deadline if (cfg.deadline is not None
                          and not cfg.deadline.is_inert()) else None
    robust = cfg.fault_plan is not None or dl is not None
    trace = (cfg.fault_plan or FaultPlan()).realize(
        cfg.n_clients, cfg.rounds) if robust else None
    arrivals = ArrivalModel(channel, dl, cfg.n_clients) \
        if dl is not None else None
    tracker = StalenessTracker(cfg.n_clients, StalenessConfig(
        alpha=cfg.staleness_alpha, a=cfg.staleness_a,
        max_staleness=cfg.max_staleness), deadline=dl,
        arrivals=arrivals) if robust else None
    codec = get_codec(cfg.uplink_codec)
    codec_key = jax.random.fold_in(key, 0x0C0DEC)
    # legacy-loop codec roundtrip (the engine vmaps the same function inside
    # the fused step, so ledger totals agree engine-vs-loop)
    rt_jit = None if codec is None else jax.jit(
        lambda k, t, rf, m: codec_mod.roundtrip(codec, k, t, ref=rf,
                                                bit_weights=m))
    rt_lora_jit = None if codec is None else jax.jit(
        lambda k, t, rf: codec_mod.roundtrip(codec, k, t, ref=rf))

    # ---- hot paths: personalized double-reward quality + PPO phases
    def quality_fn(toks, mask, ah, asafe):
        return (ah * rm_h.score(rm_h_params, toks, mask)
                + asafe * rm_s.score(rm_s_params, toks, mask))

    ppo_trainer = PPOTrainer(model, opt, cfg.ppo, cfg.prompt_len)
    gen_jit = jax.jit(lambda p, prompts, k, temp: generate(
        model, p, prompts, cfg.gen_len, k, temperature=temp))
    # factored serving: personalized LoRA threaded unmerged through
    # prefill + every decode step (shepherd eval)
    gen_lora_jit = jax.jit(lambda p, lo, prompts, k, temp: generate(
        model, p, prompts, cfg.gen_len, k, temperature=temp, lora=lo,
        lora_scale=lscale))
    quality_jit = jax.jit(quality_fn)
    l2_jit = jax.jit(trees.tree_l2)

    # fixed eval prompt sets per client (reduces round-to-round variance)
    eval_prompts = []
    for ci in range(cfg.n_clients):
        s = corpus.sample(2 * cfg.rollout_batch, topic_probs=topic_prefs[ci],
                          rng=np.random.RandomState(1000 + ci))
        eval_prompts.append(jnp.asarray(s["tokens"][:, :cfg.prompt_len]))

    def eval_reward(client_params_list, loras=None):
        """Mean personalized quality reward on the fixed eval prompts.
        ``loras[ci]`` (optional) serves client ci's LoRA unmerged."""
        vals = []
        for ci, p in enumerate(client_params_list):
            if loras is not None:
                toks = gen_lora_jit(p, loras[ci], eval_prompts[ci],
                                    jax.random.fold_in(key, 999 + ci), 0.8)
            else:
                toks = gen_jit(p, eval_prompts[ci],
                               jax.random.fold_in(key, 999 + ci), 0.8)
            mask = jnp.concatenate(
                [jnp.zeros((toks.shape[0], cfg.prompt_len)),
                 jnp.ones((toks.shape[0], cfg.gen_len))], axis=1)
            vals.append(float(quality_jit(toks, mask, prefs[ci].alpha_help,
                                          prefs[ci].alpha_safe).mean()))
        return float(np.mean(vals))

    # ---- cohort engine: the whole round is one fused jitted step; with a
    # mesh the stacked client axis is sharded over it (ghost-padded to the
    # shard count, ghosts carrying zero aggregation weight)
    use_engine = cfg.engine
    cs = cohort_sharding(mesh, cfg.n_clients, client_axes) \
        if (mesh is not None and use_engine) else None
    pending = None
    if use_engine:
        pad = cs.pad if cs is not None else (lambda xs: xs)
        mesh_kw = dict(mesh=cs.mesh if cs is not None else None,
                       client_axes=cs.axes if cs is not None else None)
        _shard = (lambda x: jax.device_put(x, cs.named)) \
            if cs is not None else (lambda x: x)
        if cfg.method == "shepherd":
            round_step = build_supervised_round(shepherd_local_step,
                                                codec=codec,
                                                factored_agg=cfg.factored_agg,
                                                robust=robust,
                                                min_quorum=(dl.min_quorum
                                                            if dl else 0),
                                                health=health,
                                                **mesh_kw)
            cohort_tr = _shard(trees.stack(pad([cl["lora"]
                                                for cl in clients])))
            cohort_opt = _shard(trees.stack(pad([cl["opt_state"]
                                                 for cl in clients])))
            payloads = [tree_bytes(cl["lora"]) for cl in clients]
            stacker = HostBatchStacker(
                sharding=cs.named if cs is not None else None)
        else:
            ppo_round_step = build_ppo_round(
                model, opt, cfg.ppo, cfg.prompt_len, cfg.gen_len, quality_fn,
                lambda_regs=pad([p.lambda_reg for p in prefs]), codec=codec,
                robust=robust,
                min_quorum=(dl.min_quorum if dl else 0), **mesh_kw)
            cohort_tr = _shard(trees.stack(pad([cl["params"]
                                                for cl in clients])))
            cohort_opt = _shard(trees.stack(pad([cl["opt_state"]
                                                 for cl in clients])))
            st_masks = _shard(trees.stack(pad(client_masks)))
            alphas_h = _shard(jnp.asarray(pad([p.alpha_help for p in prefs])))
            alphas_s = _shard(jnp.asarray(pad([p.alpha_safe for p in prefs])))
            if cs is not None:   # global model: explicitly replicated
                global_params = jax.device_put(global_params, cs.replicated)
            payloads = [tree_bytes(clients[ci]["params"],
                                   nonzero_mask=client_masks[ci])
                        for ci in range(cfg.n_clients)]
        if robust:   # device-side pending-payload buffer (zeros never merge:
            pending = jax.tree_util.tree_map(  # their agg weight is 0)
                jnp.zeros_like, cohort_tr)
    elif robust:     # legacy-loop pending buffers (parity oracle)
        kind = "lora" if cfg.method == "shepherd" else "params"
        pending_list = [jax.tree_util.tree_map(jnp.zeros_like, cl[kind])
                        for cl in clients]

    def _vec(v, fill=0.0):
        """Device round vector, ghost-padded with ``fill``."""
        return jax.device_put(cs.pad_vec(v, fill), cs.named) \
            if cs is not None else jnp.asarray(v)

    # scheduling-size estimate for the continuous-time round (see
    # wireless/arrivals.py): exact for uncompressed uploads; codec fresh
    # uploads reserve the worst-case encoded size until the first realized
    # size replaces it
    est_bits = None
    if dl is not None:
        kind = "lora" if cfg.method == "shepherd" else "params"
        if codec is None:
            est_bits = np.asarray(
                [tree_bytes(cl[kind],
                            nonzero_mask=(client_masks[ci]
                                          if kind == "params" else None)) * 8
                 for ci, cl in enumerate(clients)], np.float64)
        else:
            est_bits = np.asarray(
                [codec_mod.payload_bits_upper_bound(codec, cl[kind])
                 for cl in clients], np.float64)

    def _round_reports(rplan, charged, gains):
        """Per-attempt channel reports; deadline mode charges every
        attempt's airtime and books bytes only on delivery."""
        if dl is None:
            return [budget.report(charged[ci], gains[ci])
                    for ci in range(cfg.n_clients) if rplan.attempt[ci] > 0]
        return [budget.attempt_report(
                    charged[ci], gains[ci],
                    tx_time_s=float(rplan.tx_time_s[ci]),
                    arrival_s=float(rplan.arrival_s[ci]),
                    delivered=bool(rplan.delivered[ci] > 0))
                for ci in range(cfg.n_clients) if rplan.attempt[ci] > 0]

    def _round_extra(rplan, fresh):
        """Ledger extras for the continuous-time round; also rolls the
        realized encoded sizes into the next scheduling estimate."""
        nonlocal est_bits
        if dl is None:
            return None
        if codec is not None:
            est_bits = np.where(np.asarray(rplan.train) > 0, fresh, est_bits)
        return {"sim_dt_s": float(rplan.sim_dt_s),
                "quorum_noop": not rplan.quorum_ok,
                "n_delivered": int(rplan.n_delivered),
                "corrupt": int(np.asarray(rplan.corrupt).sum())}

    tele.start({"mode": "cohort", "method": cfg.method,
                "n_clients": cfg.n_clients, "rounds": cfg.rounds,
                "engine": bool(use_engine), "codec": cfg.uplink_codec})
    profiling = bool(tele_cfg and tele_cfg.jax_profile) and jax_profile_start(
        os.path.join(tele_cfg.out_dir, "jax_profile"))

    for rnd in range(cfg.rounds):
        gains = channel.realize(cfg.n_clients)
        rplan = None
        if robust:
            rf = trace.round(rnd)
            gains = gains * rf.gain_scale       # injected SNR dips
            rplan = tracker.begin_round(rf, channel.outage_weights(gains),
                                        gains=gains, fresh_bits=est_bits)
        rnd_key = jax.random.fold_in(codec_key, rnd)
        reports = []
        hstats = None
        ontime = None
        if robust:
            # deadline mode hands the engine the pre-deadline weights plus
            # the on-time mask; their product (applied in the fused body)
            # is the pre-quorum agg_w, and the body re-derives the quorum
            # gate so engine and legacy loop agree bit-for-bit
            ontime = rplan.ontime if dl is not None \
                else np.ones(cfg.n_clients, np.float32)
        if use_engine:
            w = (rplan.agg_w_pre if dl is not None else rplan.agg_w) \
                if robust else channel.outage_weights(gains)
            weights = jax.device_put(cs.pad_weights(w), cs.named) \
                if cs is not None else jnp.asarray(w)
            margs = (_vec(rplan.train, 1.0), weights, _vec(rplan.recv, 1.0),
                     _vec(rplan.rejoin, 0.0),
                     _vec(ontime, 1.0)) if robust else None
            ck = None
            if codec is not None:
                ck = jnp.stack(pad([jax.random.fold_in(rnd_key, ci)
                                    for ci in range(cfg.n_clients)]))
                if cs is not None:
                    ck = jax.device_put(ck, cs.named)
            if cfg.method == "shepherd":
                def shepherd_batch(ci):
                    s = corpus.sample(cfg.rollout_batch,
                                      topic_probs=topic_prefs[ci],
                                      helpful_p=0.9, unsafe_p=0.05, rng=rng)
                    return {"tokens": s["tokens"][:, :-1],
                            "labels": s["tokens"][:, 1:],
                            "mask": s["mask"][:, 1:]}
                with tracer.span("gather"):
                    batches = stacker(pad(
                        [[shepherd_batch(ci)
                          for _ in range(cfg.shepherd_steps)]
                         for ci in range(cfg.n_clients)]))
                if robust and codec is None:
                    with tracer.span("device-step"):
                        outs = round_step(
                            cohort_tr, cohort_opt, pending, batches, *margs)
                    cohort_tr, cohort_opt, pending = outs[:3]
                    bits = [payloads[ci] * 8 for ci in range(cfg.n_clients)]
                elif robust:
                    with tracer.span("device-step"):
                        outs = round_step(cohort_tr, cohort_opt, pending,
                                          batches, *margs, ck)
                    cohort_tr, cohort_opt, pending = outs[:3]
                    bits = [float(b)
                            for b in np.asarray(outs[4])[:cfg.n_clients]]
                elif codec is None:
                    with tracer.span("device-step"):
                        outs = round_step(
                            cohort_tr, cohort_opt, batches, weights)
                    cohort_tr, cohort_opt = outs[:2]
                    bits = [payloads[ci] * 8 for ci in range(cfg.n_clients)]
                else:
                    with tracer.span("device-step"):
                        outs = round_step(
                            cohort_tr, cohort_opt, batches, weights, ck)
                    cohort_tr, cohort_opt = outs[:2]
                    bits = [float(b)
                            for b in np.asarray(outs[3])[:cfg.n_clients]]
                if health:
                    hstats = outs[-1]
                for cl, lo in zip(clients,
                                  trees.unstack(cohort_tr, cfg.n_clients)):
                    cl["lora"] = lo
            else:
                with tracer.span("gather"):
                    prompts = _shard(jnp.asarray(np.stack(pad(
                        [corpus.sample(cfg.rollout_batch,
                                       topic_probs=topic_prefs[ci],
                                       rng=rng)["tokens"][:, :cfg.prompt_len]
                         for ci in range(cfg.n_clients)]))))
                    keys = _shard(jnp.stack(pad(
                        [jax.random.fold_in(key, rnd * 17 + ci)
                         for ci in range(cfg.n_clients)])))
                if robust and codec is None:
                    with tracer.span("device-step"):
                        (cohort_tr, cohort_opt, global_params, pending, _,
                         _) = ppo_round_step(cohort_tr, cohort_opt,
                                             global_params, pending, st_masks,
                                             prompts, keys, alphas_h,
                                             alphas_s, weights,
                                             _vec(rplan.train, 1.0),
                                             _vec(rplan.recv, 1.0),
                                             _vec(rplan.rejoin, 0.0),
                                             _vec(ontime, 1.0))
                    bits = [payloads[ci] * 8 for ci in range(cfg.n_clients)]
                elif robust:
                    with tracer.span("device-step"):
                        (cohort_tr, cohort_opt, global_params, pending, _, _,
                         eng_bits) = ppo_round_step(
                            cohort_tr, cohort_opt, global_params, pending,
                            st_masks, prompts, keys, alphas_h, alphas_s,
                            weights, _vec(rplan.train, 1.0),
                            _vec(rplan.recv, 1.0), _vec(rplan.rejoin, 0.0),
                            _vec(ontime, 1.0), ck)
                    bits = [float(b)
                            for b in np.asarray(eng_bits)[:cfg.n_clients]]
                elif codec is None:
                    with tracer.span("device-step"):
                        (cohort_tr, cohort_opt, global_params, _,
                         _) = ppo_round_step(cohort_tr, cohort_opt,
                                             global_params, st_masks, prompts,
                                             keys, alphas_h, alphas_s,
                                             weights)
                    bits = [payloads[ci] * 8 for ci in range(cfg.n_clients)]
                else:
                    with tracer.span("device-step"):
                        (cohort_tr, cohort_opt, global_params, _, _,
                         eng_bits) = ppo_round_step(
                            cohort_tr, cohort_opt, global_params, st_masks,
                            prompts, keys, alphas_h, alphas_s, weights, ck)
                    bits = [float(b)
                            for b in np.asarray(eng_bits)[:cfg.n_clients]]
                for cl, p in zip(clients,
                                 trees.unstack(cohort_tr, cfg.n_clients)):
                    cl["params"] = p
            extra = None
            if robust:
                fresh = np.asarray(bits, np.float64)
                charged = tracker.end_round(rplan, fresh)
                reports = _round_reports(rplan, charged, gains)
                extra = _round_extra(rplan, fresh)
            else:
                reports = budget.round_reports(bits, gains)
            ledger.log_round(reports, extra, round_id=rnd)
            # (aggregation + broadcast already fused into the round step)
        else:
            fresh = np.zeros(cfg.n_clients, np.float64)
            for ci, cl in enumerate(clients):
                if cfg.method == "shepherd":
                    # draw the round's batches even when a fault skips this
                    # client — keeps the host RNG stream aligned with the
                    # engine (and with the fault-free run)
                    samples = [corpus.sample(cfg.rollout_batch,
                                             topic_probs=topic_prefs[ci],
                                             helpful_p=0.9, unsafe_p=0.05,
                                             rng=rng)
                               for _ in range(cfg.shepherd_steps)]
                    if robust and rplan.train[ci] == 0:
                        continue
                    ref = cl["lora"] if codec is not None else None
                    for s in samples:
                        toks = jnp.asarray(s["tokens"])
                        batch = {"tokens": toks[:, :-1],
                                 "labels": toks[:, 1:],
                                 "mask": jnp.asarray(s["mask"][:, 1:])}
                        cl["lora"], cl["opt_state"], _ = shepherd_step(
                            cl["lora"], cl["opt_state"], batch)
                    if codec is None:
                        fresh[ci] = tree_bytes(cl["lora"]) * 8
                    else:
                        dec, b = rt_lora_jit(
                            jax.random.fold_in(rnd_key, ci), cl["lora"], ref)
                        cl["decoded_upload"] = dec
                        fresh[ci] = float(b)
                    if not robust:
                        reports.append(budget.report(fresh[ci], gains[ci]))
                    continue

                # --- PPO with the personalized reward
                s = corpus.sample(cfg.rollout_batch,
                                  topic_probs=topic_prefs[ci], rng=rng)
                if robust and rplan.train[ci] == 0:
                    continue
                ref = cl["params"] if codec is not None else None
                prompts = jnp.asarray(s["tokens"][:, :cfg.prompt_len])
                toks = gen_jit(cl["params"], prompts,
                               jax.random.fold_in(key, rnd * 17 + ci),
                               cfg.ppo.temperature)
                mask = jnp.concatenate(
                    [jnp.zeros((toks.shape[0], cfg.prompt_len)),
                     jnp.ones((toks.shape[0], cfg.gen_len))], axis=1)
                reward = quality_jit(toks, mask, prefs[ci].alpha_help,
                                     prefs[ci].alpha_safe)
                if prefs[ci].lambda_reg > 0:
                    reg = l2_jit(
                        trees.select(cl["params"],
                                     lambda p: p.startswith("stages")),
                        trees.select(global_params,
                                     lambda p: p.startswith("stages")))
                    reward = reward - prefs[ci].lambda_reg * reg
                cl["params"], cl["opt_state"], _ = ppo_trainer.round(
                    cl["params"], global_params, cl["opt_state"],
                    toks, reward, grad_mask=client_masks[ci])
                if codec is None:
                    fresh[ci] = tree_bytes(cl["params"],
                                           nonzero_mask=client_masks[ci]) * 8
                else:
                    dec, b = rt_jit(jax.random.fold_in(rnd_key, ci),
                                    cl["params"], ref, client_masks[ci])
                    cl["decoded_upload"] = dec
                    fresh[ci] = float(b)
                if not robust:
                    reports.append(budget.report(fresh[ci], gains[ci]))
            extra = None
            if robust:
                charged = tracker.end_round(rplan, fresh)
                reports = _round_reports(rplan, charged, gains)
                extra = _round_extra(rplan, fresh)
            ledger.log_round(reports, extra, round_id=rnd)

            def upload(ci, kind):
                if codec is not None:
                    return clients[ci]["decoded_upload"]
                return clients[ci][kind]

            # --- aggregation (over the lossy decoded uploads with a codec)
            if robust:
                # legacy mirror of the robust fused body: same stacked ops,
                # same tracker outputs (fresh uploads supersede pending,
                # stragglers retransmit, recv gates the broadcast, rejoin
                # resets the optimizer)
                kind = "lora" if cfg.method == "shepherd" else "params"
                send_list = [upload(ci, kind) if rplan.train[ci] > 0
                             else pending_list[ci]
                             for ci in range(cfg.n_clients)]
                pending_list = send_list
                aggw = jnp.asarray(rplan.agg_w)
                if float(rplan.agg_w.sum()) > 0:
                    st_send = trees.stack(send_list)
                    if cfg.method == "shepherd":
                        agg = (factored_fedavg_stacked(st_send, aggw)
                               if cfg.factored_agg
                               else fedavg_stacked(st_send, aggw))
                        for ci, cl in enumerate(clients):
                            if rplan.recv[ci] > 0:
                                cl["lora"] = agg
                    else:
                        global_params = masked_fedavg_stacked(
                            global_params, st_send,
                            trees.stack(client_masks), aggw)
                        for ci, cl in enumerate(clients):
                            if rplan.recv[ci] > 0:
                                cl["params"] = jax.tree_util.tree_map(
                                    lambda loc, glob, m: jnp.where(
                                        jnp.broadcast_to(m, loc.shape) > 0,
                                        glob, loc),
                                    cl["params"], global_params,
                                    client_masks[ci])
                for ci, cl in enumerate(clients):
                    if rplan.rejoin[ci] > 0:
                        cl["opt_state"] = jax.tree_util.tree_map(
                            jnp.zeros_like, cl["opt_state"])
            else:
                alive = [ci for ci, r in enumerate(reports) if not r.outage]
                if alive and cfg.method == "shepherd":
                    ups = [upload(ci, "lora") for ci in alive]
                    if cfg.factored_agg:
                        agg = factored_fedavg_stacked(trees.stack(ups))
                    else:
                        agg = fedavg(ups)
                    for cl in clients:
                        cl["lora"] = agg
                elif alive:
                    global_params = masked_fedavg(
                        global_params,
                        [upload(ci, "params") for ci in alive],
                        [client_masks[ci] for ci in alive])
                    # broadcast: clients resume from global on masked entries
                    for ci, cl in enumerate(clients):
                        cl["params"] = jax.tree_util.tree_map(
                            lambda loc, glob, m: jnp.where(
                                jnp.broadcast_to(m, loc.shape) > 0, glob, loc),
                            cl["params"], global_params, client_masks[ci])

        with tracer.span("eval"):
            if cfg.method == "shepherd":
                if cfg.factored:   # serve unmerged: base broadcast, tiny factors
                    reward_curve.append(eval_reward(
                        [global_params] * cfg.n_clients,
                        loras=[cl["lora"] for cl in clients]))
                else:
                    reward_curve.append(eval_reward(
                        [peft_mod.merge_lora(global_params,
                                             clients[ci]["lora"], peft_cfg)
                         for ci in range(cfg.n_clients)]))
            else:
                reward_curve.append(
                    eval_reward([cl["params"] for cl in clients]))
        if tele.enabled:
            if rnd == 0:
                tele.compile_event(rnd,
                                   tracer.totals().get("device-step", 0.0))
            tele.round_event(rnd, {
                "reward": reward_curve[-1],
                "cohort": None,
                "comm": {k: v for k, v in ledger.rounds[-1].items()
                         if k != "per_client"},
                "staleness": tracker.counters() if robust else None,
                "health": None if hstats is None
                else {k: float(v) for k, v in hstats.items()},
            }, wall={"phases": tracer.pop_round()})
        if cfg.verbose:
            print(f"[pfit:{cfg.method}] round {rnd} reward "
                  f"{reward_curve[-1]:.4f} bytes {ledger.rounds[-1]['bytes']:,}")

    if profiling:
        jax_profile_stop()
    tele.close()
    return {
        "method": cfg.method,
        "reward_per_round": reward_curve,
        "final_reward": reward_curve[-1],
        "mean_round_bytes": ledger.mean_round_bytes,
        "mean_round_delay_s": ledger.mean_round_delay,
        "total_bytes": ledger.total_bytes,
        "total_energy_j": ledger.total_energy_j,
        "total_sim_time_s": ledger.total_sim_time_s,
        "quorum_noops": ledger.quorum_noops,
        "uplink_codec": cfg.uplink_codec,
        "rm_pair_acc": {"help": rmh_stats["pair_acc"],
                        "safe": rms_stats["pair_acc"]},
    }


def _run_pfit_population(cfg: PFITConfig, mesh=None, client_axes=None) -> Dict:
    """Sampled-cohort population mode for the shepherd baseline: a
    ``PopulationStore`` of per-client LoRA/opt/pending trees over
    ``population`` clients, per-round sampling + gather/scatter around the
    SAME fused supervised round body, the ``StalenessTracker`` spanning the
    population.  Non-IID here means per-client TOPIC skew (the scenario's
    Dirichlet draw is over the instruction corpus's ``N_TOPICS``).  PPO
    methods are rejected: they train full per-client parameter trees, which
    don't fit the KB-per-client regime that makes a 10k-client host store
    viable — that's exactly what shepherd's rank-r factors buy."""
    from repro.fl.population import (ClientSampler, PopulationData,
                                     PopulationRunner, PopulationStore,
                                     stacked_client_init)
    from repro.wireless.scenarios import Scenario

    pop = cfg.population
    if cfg.method != "shepherd":
        raise ValueError(
            "population mode supports the shepherd (supervised LoRA) "
            f"method only, not {cfg.method!r}: PPO methods carry full "
            "per-client parameter trees, which don't fit the "
            "KB-per-client population regime")
    if not cfg.engine:
        raise ValueError("population mode runs the fused engine only")
    N, K = pop.population, pop.cohort_size
    scen = pop.scenario or Scenario(n_classes=N_TOPICS)
    if scen.n_classes != N_TOPICS:
        raise ValueError(f"pfit population scenarios partition over the "
                         f"instruction corpus's {N_TOPICS} topics; got "
                         f"n_classes={scen.n_classes}")

    key = jax.random.PRNGKey(cfg.seed)
    rng = np.random.RandomState(cfg.seed)
    meshctx = MeshCtx.single_device()
    mcfg = get_config("gpt2-small").reduced(d_model=cfg.d_model,
                                            repeats=cfg.n_layers)
    model = Model(mcfg, meshctx=meshctx)
    corpus = InstructionCorpus(seq_len=cfg.prompt_len + cfg.gen_len,
                               prompt_len=cfg.prompt_len, seed=cfg.seed)
    params = model.init(key)
    params = _pretrain_policy(key, model, params, corpus, cfg.pretrain_steps,
                              cfg.pretrain_lr, 16, cfg.verbose)
    global_params = params

    strace = scen.realize(N, cfg.rounds)
    pool_n = int(np.clip(cfg.rollout_batch * 64, 512, 4096))
    pool = corpus.sample(pool_n, helpful_p=0.9, unsafe_p=0.05, rng=rng)
    data = PopulationData(pool, strace.class_probs, seed=cfg.seed,
                          label_key="topic")

    peft_cfg = peft_mod.PEFTConfig(lora_rank=cfg.lora_rank,
                                   lora_targets=("mixer/wq", "mixer/wv"))
    lscale = peft_mod.lora_scale(peft_cfg)
    opt = adamw(cfg.lr)
    upload_pred = lambda p: True            # shepherd uploads the whole LoRA

    def client_init(ck):
        lora = peft_mod.init_lora(ck, params, peft_cfg)
        return {"t": lora, "o": opt.init(lora)}

    keys = jax.vmap(lambda i: jax.random.fold_in(key, 200 + i))(
        jnp.arange(N))
    stacked = stacked_client_init(client_init, keys)
    pend_np = jax.tree_util.tree_map(np.zeros_like, stacked["t"])
    store = PopulationStore({"trainable": stacked["t"], "opt": stacked["o"],
                             "pending": pend_np})
    lora0 = store.row("trainable", 0)
    global_shared = jax.tree_util.tree_map(np.array, lora0)

    channel = RayleighChannel(mean_snr_db=cfg.snr_db, seed=cfg.seed)
    budget = ChannelBudget(channel, tx_power_w=cfg.tx_power_w)
    ledger = CommLedger()
    dl = cfg.deadline if (cfg.deadline is not None
                          and not cfg.deadline.is_inert()) else None
    trace = (cfg.fault_plan or FaultPlan()).realize(N, cfg.rounds)
    arrivals = ArrivalModel(channel, dl, N) if dl is not None else None
    tracker = StalenessTracker(N, StalenessConfig(
        alpha=cfg.staleness_alpha, a=cfg.staleness_a,
        max_staleness=cfg.max_staleness), deadline=dl, arrivals=arrivals)
    codec = get_codec(cfg.uplink_codec)
    codec_key = None if codec is None else jax.random.fold_in(key, 0x0C0DEC)
    payload_bits = tree_bytes(lora0) * 8
    est_bits = None
    if dl is not None:
        est_bits = np.full(N, payload_bits if codec is None else
                           codec_mod.payload_bits_upper_bound(codec, lora0),
                           np.float64)

    def shepherd_local_step(lora, opt_state, batch):
        def loss_fn(lo):
            if cfg.factored:
                return model.lm_loss(global_params, batch, lora=lo,
                                     lora_scale=lscale)
            eff = peft_mod.apply_lora(global_params, lo, peft_cfg)
            return model.lm_loss(eff, batch)
        loss, g = jax.value_and_grad(loss_fn)(lora)
        upd, opt_state = opt.update(g, opt_state, lora)
        return trees.tree_add(lora, upd), opt_state, loss

    tele_cfg = cfg.telemetry
    tracer = SpanTracer(enabled=bool(tele_cfg and tele_cfg.trace))
    tele = RunTelemetry(tele_cfg.out_dir if tele_cfg else None, tracer=tracer)
    health = bool(tele_cfg and tele_cfg.health)

    cs = cohort_sharding(mesh, K, client_axes) if mesh is not None else None
    round_step = build_supervised_round(
        shepherd_local_step,
        mesh=cs.mesh if cs is not None else None,
        client_axes=cs.axes if cs is not None else None,
        codec=codec, factored_agg=cfg.factored_agg, robust=True,
        min_quorum=(dl.min_quorum if dl is not None else 0),
        health=health)
    stacker = HostBatchStacker(sharding=cs.named if cs is not None else None)

    runner = PopulationRunner(
        pop=pop, store=store, global_shared=global_shared,
        upload_pred=upload_pred, channel=channel, budget=budget,
        ledger=ledger, tracker=tracker, trace=trace, strace=strace,
        sampler=ClientSampler(pop.sampler, N, K,
                              seed=cfg.seed + 1000 * pop.seed),
        arrivals=arrivals, dl=dl, cs=cs, est_bits=est_bits,
        tracer=tracer, health=health)

    def _lm_batch(b):
        return {"tokens": b["tokens"][:, :-1], "labels": b["tokens"][:, 1:],
                "mask": b["mask"][:, 1:]}

    def draw(cid, rnd):
        return [_lm_batch(b) for b in data.round_batches(
            cid, rnd, cfg.shepherd_steps, cfg.rollout_batch)]

    # ---- cohort eval: per-client LM loss on a held-out topical draw, one
    # fused dispatch per round (generation+reward eval stays in cohort mode
    # — it is per-client-sequential and would dominate a population run)
    n_rows = cs.total if cs is not None else K
    n_eval = min(2 * cfg.rollout_batch, 64)
    seq = corpus.seq_len - 1
    e_toks = np.zeros((n_rows, n_eval, seq), np.int32)
    e_labels = np.zeros((n_rows, n_eval, seq), np.int32)
    e_mask = np.zeros((n_rows, n_eval, seq), np.float32)
    _put = (lambda x: jax.device_put(x, cs.named)) if cs is not None \
        else jnp.asarray

    def eval_client(lora, tokens, labels, mask):
        batch = {"tokens": tokens, "labels": labels, "mask": mask}
        if cfg.factored:
            return model.lm_loss(global_params, batch, lora=lora,
                                 lora_scale=lscale)
        eff = peft_mod.apply_lora(global_params, lora, peft_cfg)
        return model.lm_loss(eff, batch)

    eval_cohort = build_cohort_eval(
        eval_client, sharding=cs.named if cs is not None else None)
    test_cache: Dict[int, Dict] = {}

    def eval_ids(cohort_tr, ids):
        if len(test_cache) > 4096:
            test_cache.clear()
        for j, cid in enumerate(ids):
            te = test_cache.get(int(cid))
            if te is None:
                te = _lm_batch(data.test_set(int(cid), n_eval))
                test_cache[int(cid)] = te
            e_toks[j], e_labels[j], e_mask[j] = \
                te["tokens"], te["labels"], te["mask"]
        losses = eval_cohort(cohort_tr, _put(e_toks), _put(e_labels),
                             _put(e_mask))
        return [float(l) for l in np.asarray(losses)[:len(ids)]]

    tele.start({"mode": "population", "method": cfg.method,
                "population": N, "cohort_size": K, "rounds": cfg.rounds,
                "sampler": pop.sampler, "codec": cfg.uplink_codec})
    profiling = bool(tele_cfg and tele_cfg.jax_profile) and jax_profile_start(
        os.path.join(tele_cfg.out_dir, "jax_profile"))

    loss_per_round: List[float] = []
    for rnd in range(cfg.rounds):
        out = runner.run_round(rnd, round_step=round_step, stacker=stacker,
                               draw_batches=draw,
                               local_steps=cfg.shepherd_steps,
                               payload_bits=payload_bits,
                               codec_key=codec_key)
        with tracer.span("eval"):
            loss_per_round.append(
                float(np.mean(eval_ids(out["cohort_tr"], out["ids"]))))
        if tele.enabled:
            if rnd == 0:
                tele.compile_event(rnd,
                                   tracer.totals().get("device-step", 0.0))
            tele.round_event(rnd, {
                "eval_loss": loss_per_round[-1],
                "cohort": [int(i) for i in out["ids"]],
                "comm": {k: v for k, v in ledger.rounds[-1].items()
                         if k != "per_client"},
                "staleness": tracker.counters(),
                "health": out["health"],
            }, wall={"phases": tracer.pop_round()})
        if cfg.verbose:
            print(f"[pfit-pop:shepherd] round {rnd} "
                  f"cohort lm-loss {loss_per_round[-1]:.4f}")

    if profiling:
        jax_profile_stop()
    tele.close()
    return {
        "method": cfg.method,
        "eval_loss_per_round": loss_per_round,
        "final_eval_loss": loss_per_round[-1] if loss_per_round else 0.0,
        "mean_round_bytes": ledger.mean_round_bytes,
        "mean_round_delay_s": ledger.mean_round_delay,
        "total_bytes": ledger.total_bytes,
        "total_energy_j": ledger.total_energy_j,
        "total_sim_time_s": ledger.total_sim_time_s,
        "quorum_noops": ledger.quorum_noops,
        "uplink_codec": cfg.uplink_codec,
        "population": N,
        "cohort_size": K,
        "sampler": pop.sampler,
        "scenario": scen.to_dict(),
        "participation_frac": float(runner.seen.mean()),
        "host_overhead_frac": runner.host_overhead_frac,
        "store_bytes": store.nbytes(),
    }
