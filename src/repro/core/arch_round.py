"""Generic per-architecture fused federated round — the config-zoo scenario.

``run_arch_round`` runs a reduced FedLoRA-style cohort round on ANY
``configs/`` architecture (dense gpt2, MLA deepseek, SSM mamba/jamba, MoE
dbrx, enc-dec whisper): per-client rank-r LoRA factor trees train through
``core/cohort.build_supervised_round`` — one fused vmapped (and optionally
``shard_map``-sharded) step per round — against the replicated frozen base,
with FedAvg over the factors and broadcast-back inside the compiled step.

This is the CI ``arch-matrix`` workload (`launch/train.py --fl-clients N
--arch <zoo>`): every cell proves the UNIVERSAL fused path —

* the LoRA side channel stays factored through every mixer family
  (``peft.dense_merge_count()`` must not move while the engine runs);
* ragged cohorts (unequal per-client batch sizes, the default here) compile
  to ONE dispatch per round via the ``HostBatchStacker`` pad-and-mask
  machinery (the ``"valid"`` sample weights fold into the LM token mask);
* ``oracle=True`` replays the identical padded batches through the legacy
  per-client dense-merge loop (``peft.apply_lora`` each step) and reports
  the max per-(round, client, step) loss deviation — the factored fused
  round must match the dense-merge oracle to ≤1e-5.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.configs import get_config
from repro.core.aggregation import fedavg_stacked
from repro.core.cohort import HostBatchStacker, build_supervised_round
from repro.models import Model
from repro.models import peft as peft_mod
from repro.optim import adamw
from repro.sharding import MeshCtx, cohort_sharding

# which mixer projections carry LoRA per layer family — the universal
# factored contract (models/mla.py, models/ssm.py, blocks._qkv)
MIXER_TARGETS = {
    "attn": ("mixer/wq", "mixer/wv"),
    "local": ("mixer/wq", "mixer/wv"),
    "enc": ("mixer/wq", "mixer/wv"),
    "dec": ("mixer/wq", "mixer/wv"),
    "mla": ("mixer/wq_a", "mixer/wq_b", "mixer/wkv_a", "mixer/wkv_b"),
    "mamba": ("mixer/in_proj", "mixer/out_proj"),
}


def arch_lora_targets(mcfg) -> tuple:
    """LoRA target paths covering every mixer family in the config's
    stage patterns."""
    targets = []
    for stage in mcfg.stages:
        for kind in stage.pattern:
            for t in MIXER_TARGETS.get(kind.mixer, ()):
                if t not in targets:
                    targets.append(t)
    return tuple(targets)


@dataclasses.dataclass(frozen=True)
class ArchRoundConfig:
    arch: str
    n_clients: int = 4
    rounds: int = 2
    local_steps: int = 2
    batch: int = 4
    seq_len: int = 16
    d_model: int = 64
    repeats: int = 1
    lora_rank: int = 4
    lr: float = 1e-3
    seed: int = 0
    ragged: bool = True    # vary per-client batch size (pad-and-mask path)
    oracle: bool = False   # replay the legacy dense-merge loop, report parity


def _draw_round_batches(mcfg, rng, sizes, local_steps, seq_len):
    """[client][step] host LM batches; the sample axis is ragged when
    ``sizes`` differ (the stacker pads and masks)."""
    out = []
    for b in sizes:
        steps = []
        for _ in range(local_steps):
            toks = rng.randint(6, mcfg.vocab_size, size=(b, seq_len + 1))
            batch = {"tokens": toks[:, :-1].astype(np.int32),
                     "labels": toks[:, 1:].astype(np.int32),
                     "mask": np.ones((b, seq_len), np.float32)}
            if mcfg.is_encoder_decoder:
                batch["frames"] = rng.randn(
                    b, mcfg.encoder_seq, mcfg.d_model).astype(np.float32)
            if mcfg.n_prefix_tokens:
                batch["patches"] = rng.randn(
                    b, mcfg.n_prefix_tokens, mcfg.prefix_dim).astype(np.float32)
            steps.append(batch)
        out.append(steps)
    return out


def _fold_valid(batch):
    """Padded-row sample weights → the LM token mask (exact: padded rows
    then weigh zero in lm_loss's tot/cnt)."""
    b = dict(batch)
    v = b.pop("valid", None)
    if v is not None:
        b["mask"] = b["mask"] * v[:, None]
    return b


def run_arch_round(cfg: ArchRoundConfig, mesh=None,
                   client_axes=None) -> Dict:
    """Run the fused factored cohort round for one architecture; see the
    module docstring.  ``mesh`` shards the client axis (ghost-padding
    non-divisible cohorts)."""
    mcfg = get_config(cfg.arch).reduced(d_model=cfg.d_model,
                                        repeats=cfg.repeats)
    model = Model(mcfg, meshctx=MeshCtx.single_device())
    key = jax.random.PRNGKey(cfg.seed)
    params = model.init(key, max_seq=cfg.seq_len)
    targets = arch_lora_targets(mcfg)
    pc = peft_mod.PEFTConfig(lora_rank=cfg.lora_rank,
                             lora_alpha=2.0 * cfg.lora_rank,
                             lora_targets=targets)
    scale = peft_mod.lora_scale(pc)
    loras = [peft_mod.init_lora(jax.random.fold_in(key, 100 + ci), params, pc)
             for ci in range(cfg.n_clients)]
    opt = adamw(cfg.lr, update_mask=lambda p: not p.endswith("/mask"))

    def local_step(lora, opt_state, batch):
        def loss_fn(lf):
            return model.lm_loss(params, _fold_valid(batch), lora=lf,
                                 lora_scale=scale)
        loss, g = jax.value_and_grad(loss_fn)(lora)
        upd, opt_state = opt.update(g, opt_state, lora)
        return trees.tree_add(lora, upd), opt_state, loss

    cs = cohort_sharding(mesh, cfg.n_clients, client_axes) \
        if mesh is not None else None
    pad = cs.pad if cs is not None else (lambda xs: list(xs))
    round_step = build_supervised_round(
        local_step, None, mesh=cs.mesh if cs is not None else None,
        client_axes=cs.axes if cs is not None else None)
    cohort = trees.stack(pad(loras))
    cohort_opt = trees.stack(pad([opt.init(l) for l in loras]))
    if cs is not None:
        cohort = jax.device_put(cohort, cs.named)
        cohort_opt = jax.device_put(cohort_opt, cs.named)
    stacker = HostBatchStacker(sharding=cs.named if cs is not None else None)

    rng = np.random.RandomState(cfg.seed)
    sizes = ([max(1, cfg.batch - (ci % 2)) for ci in range(cfg.n_clients)]
             if cfg.ragged and cfg.n_clients > 1
             else [cfg.batch] * cfg.n_clients)
    round_batches = [_draw_round_batches(mcfg, rng, sizes, cfg.local_steps,
                                         cfg.seq_len)
                     for _ in range(cfg.rounds)]
    w = np.ones(cfg.n_clients, np.float32)
    weights = jax.device_put(cs.pad_weights(w), cs.named) \
        if cs is not None else jnp.asarray(w)

    eng_losses, padded_rounds = [], []
    dispatches = 0
    merges_in_engine = 0
    for rnd in range(cfg.rounds):
        batches = stacker(pad(round_batches[rnd]))
        if cfg.oracle:
            # snapshot the padded rows the engine actually sees; np.array
            # COPIES — np.asarray of a CPU jax array is a zero-copy view
            # into a device buffer that is freed when ``batches`` is rebound
            padded_rounds.append({k: np.array(v) for k, v in
                                  batches.items()})
        m0 = peft_mod.dense_merge_count()
        cohort, cohort_opt, losses = round_step(cohort, cohort_opt, batches,
                                                weights)
        merges_in_engine += peft_mod.dense_merge_count() - m0
        dispatches += 1
        eng_losses.append(np.asarray(losses)[:cfg.n_clients])

    result = {
        "arch": cfg.arch,
        "lora_targets": list(targets),
        "ragged": len(set(sizes)) > 1,
        "n_ghosts": cs.n_pad if cs is not None else 0,
        "dispatches_per_round": dispatches / max(cfg.rounds, 1),
        "dense_merges_in_engine": int(merges_in_engine),
        "loss_per_round": [float(l.mean()) for l in eng_losses],
    }

    if cfg.oracle:
        # legacy dense-merge loop over the IDENTICAL padded batches: one
        # jitted per-client step that materializes W + sAB every call
        @jax.jit
        def oracle_step(lora, opt_state, batch):
            def loss_fn(lf):
                eff = peft_mod.apply_lora(params, lf, pc)
                return model.lm_loss(eff, _fold_valid(batch))
            loss, g = jax.value_and_grad(loss_fn)(lora)
            upd, opt_state = opt.update(g, opt_state, lora)
            return trees.tree_add(lora, upd), opt_state, loss

        o_loras = list(loras)
        o_opts = [opt.init(l) for l in o_loras]
        max_err = 0.0
        for rnd in range(cfg.rounds):
            stacked = padded_rounds[rnd]
            for ci in range(cfg.n_clients):
                for si in range(cfg.local_steps):
                    batch = {k: jnp.asarray(v[ci, si])
                             for k, v in stacked.items()}
                    o_loras[ci], o_opts[ci], loss = oracle_step(
                        o_loras[ci], o_opts[ci], batch)
                    max_err = max(max_err, abs(float(loss)
                                               - eng_losses[rnd][ci, si]))
            agg = fedavg_stacked(trees.stack(o_loras),
                                 jnp.ones(cfg.n_clients))
            o_loras = [agg] * cfg.n_clients
        result["oracle_loss_max_err"] = float(max_err)

    return result
