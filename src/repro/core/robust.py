"""Host-side runtime for the straggler-tolerant federated round.

The compiled robust round step (``core/cohort.py`` with ``robust=True``)
is deliberately dumb: it consumes per-round fault masks and a pre-computed
aggregation weight vector, and carries the pending-payload buffer.  ALL the
bookkeeping that decides those inputs — which client has a payload on the
air, how stale it is, what the ``α·(1+s)^(-a)`` discount works out to, how
many bits the retransmission charges — is a pure function of host-known
quantities (fault masks + channel outage outcomes), so it lives here, on
the host, where the fused engine and the legacy per-client loop can share
it verbatim.  That sharing is what makes engine-vs-loop parity under
injected faults exact: both paths feed identical weight vectors and ledger
charges from one ``StalenessTracker``.

Per-round contract (both execution paths):

1. ``plan = tracker.begin_round(faults, outage_w)`` — ages the pending
   buffer, drops payloads staler than ``max_staleness``, decides who
   attempts an uplink (``tx`` clients holding a fresh or pending payload),
   who delivers (attempt minus channel outage), and folds the FedAsync
   discount ``α·(1+s)^(-a)`` into ``plan.agg_w``.
2. The round body runs with ``plan.train/agg_w/recv/rejoin``; training
   clients' fresh uploads supersede their pending payloads, stragglers
   retransmit the buffered one.
3. ``charged = tracker.end_round(plan, fresh_bits)`` — updates the buffer
   bookkeeping (fresh-but-undelivered payloads go pending at staleness 0;
   delivered or crash-dropped ones clear) and returns the per-client bit
   charge: fresh encode bits for training clients, the STORED encode bits
   for retransmitters (the payload on the air is the buffered one).

Silent clients (nothing on the air) are excluded from the round's channel
reports entirely — no bytes, no delay, no energy.

Under normalization the global ``α`` cancels out of
``fedavg_stacked``/``masked_fedavg_stacked`` (both divide by the weight
sum), so only the RELATIVE ``(1+s)^(-a)`` discount between fresh and stale
payloads matters; ``α`` is kept for parity with
``core/async_agg.StalenessWeightedAggregator`` and for the all-outage gate
semantics (``α > 0`` never flips the ``Σw > 0`` gate).

With the zero-fault plan every client trains and transmits every round, so
pending payloads are always superseded before they could retransmit,
staleness is identically zero, and ``agg_w`` equals the plain channel
outage weights — the robust round is then bitwise the synchronous round
for ANY ``max_staleness``.  ``max_staleness=0`` additionally makes the
robust engine drop failed uploads exactly like the synchronous engine even
under faults (a pending payload ages to 1 > 0 before its first retransmit
chance).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.wireless.arrivals import ArrivalModel, DeadlineConfig
from repro.wireless.faults import RoundFaults


@dataclasses.dataclass(frozen=True)
class StalenessConfig:
    """Bounded-staleness aggregation knobs (FedAsync-style discounting).

    ``alpha``: global merge weight α (cancels under weight normalization —
    see module docstring).  ``a``: staleness exponent; 0 disables
    discounting (stale payloads merge at full weight).  ``max_staleness``:
    pending payloads older than this many rounds are dropped, not merged;
    0 reproduces the synchronous engine's drop-on-failure semantics."""
    alpha: float = 1.0
    a: float = 0.0
    max_staleness: int = 0

    def discount(self, staleness: np.ndarray) -> np.ndarray:
        return (self.alpha
                * (1.0 + staleness.astype(np.float64)) ** (-self.a)
                ).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One round's resolved schedule (all (n_clients,) arrays).

    The continuous-time fields are only populated when the tracker runs
    with a ``DeadlineConfig`` (else they keep their inert defaults and the
    plan is exactly the PR 6 round-granular one)."""
    train: np.ndarray      # float32 — client runs local steps
    recv: np.ndarray       # float32 — client receives the broadcast
    rejoin: np.ndarray     # float32 — crash rejoin (opt state reset)
    attempt: np.ndarray    # float32 — a payload goes on the air
    delivered: np.ndarray  # float32 — attempt survived channel + checksum +
                           #           deadline + quorum
    staleness: np.ndarray  # int64   — age of the payload on the air
    agg_w: np.ndarray      # float32 — delivered · α·(1+s)^(-a) (final,
                           #           quorum-aborted rounds are all-zero)
    # ---- continuous-time extras (deadline mode) --------------------------
    ontime: Optional[np.ndarray] = None    # f32 — arrival ≤ deadline (the
                                           # engine's deadline mask input)
    corrupt: Optional[np.ndarray] = None   # f32 — checksum-NACKed attempt
    agg_w_pre: Optional[np.ndarray] = None  # f32 — discount · delivered-
                                           # before-deadline/quorum (the
                                           # engine multiplies by ``ontime``
                                           # and applies the quorum gate
                                           # in-body; ``agg_w_pre · ontime``
                                           # == pre-quorum ``agg_w``)
    arrival_s: Optional[np.ndarray] = None  # f64 — scheduled arrival time
    tx_time_s: Optional[np.ndarray] = None  # f64 — scheduled airtime
    quorum_ok: bool = True                 # round met ``min_quorum``
    n_delivered: int = 0                   # deliveries before the quorum gate
    sim_dt_s: float = 0.0                  # simulated round duration


class StalenessTracker:
    """Pending-payload bookkeeping + staleness-discounted weight vector.

    Tracks, per client: whether the pending buffer holds a real payload
    (``valid``), how many rounds old it is (``age``), and the encoded bit
    size it was produced at (``bits`` — what a retransmission charges).
    The payload *contents* live device-side in the engine's pending buffer
    (or the legacy loop's per-client list); the tracker only ever sees
    masks and sizes, which is why both paths can share one instance.

    With a ``DeadlineConfig`` + ``ArrivalModel`` the tracker additionally
    runs the continuous-time round (``wireless/arrivals.py``): per-client
    arrival times decide a deadline mask, failed attempts (outage, checksum
    NACK, deadline miss) retry under capped exponential backoff and are
    abandoned after ``max_retries``, and a round delivering fewer than
    ``min_quorum`` payloads is voided server-side (deliveries NACKed back
    to pending, no failure counted, no merge).  Passing ``deadline=None``
    is byte-for-byte the PR 6 round-granular tracker."""

    def __init__(self, n_clients: int, cfg: Optional[StalenessConfig] = None,
                 *, deadline: Optional[DeadlineConfig] = None,
                 arrivals: Optional[ArrivalModel] = None):
        self.cfg = cfg or StalenessConfig()
        self.valid = np.zeros(n_clients, bool)
        self.age = np.zeros(n_clients, np.int64)
        self.bits = np.zeros(n_clients, np.float64)
        if deadline is not None and arrivals is None:
            raise ValueError("deadline mode needs an ArrivalModel")
        self.deadline = deadline
        self.arrivals = arrivals
        # continuous-time state (inert until a DeadlineConfig is set)
        self.fails = np.zeros(n_clients, np.int64)     # failed attempts of
        #                                              # the current payload
        self.next_try_s = np.zeros(n_clients, np.float64)  # backoff window
        self.now_s = 0.0                               # simulated clock
        self.quorum_noops = 0                          # voided rounds
        self.abandoned = 0                             # payloads given up
        self.retransmissions = 0                       # buffered re-sends

    def begin_round(self, faults: RoundFaults, outage_w: np.ndarray, *,
                    gains: Optional[np.ndarray] = None,
                    fresh_bits: Optional[np.ndarray] = None) -> RoundPlan:
        """Resolve the round schedule from the fault masks and the realized
        channel outage weights (1.0 delivered / 0.0 outage per client).

        Deadline mode additionally needs ``gains`` (the realized fading
        draws, dips included) and ``fresh_bits`` (the host-known encoded
        payload size each *training* client would put on the air — exact
        for uncompressed uploads, the previously realized encoded size for
        codec runs; retransmitters always use their buffered size)."""
        # payloads produced in an earlier round are one round staler now;
        # anything beyond the staleness bound is abandoned
        self.age[self.valid] += 1
        self.valid &= self.age <= self.cfg.max_staleness
        train = faults.train > 0
        if self.deadline is None:
            has_payload = train | self.valid    # fresh upload or buffered
            attempt = (faults.tx > 0) & has_payload
            # a corrupted payload fails its host-side checksum on delivery
            # and is NACKed exactly like an outage (never merged) — also in
            # the round-granular runtime (None for pre-corruption traces)
            corrupt = np.zeros(len(self.valid), bool) \
                if faults.corrupt is None else (faults.corrupt > 0)
            corrupt = corrupt & attempt
            self.retransmissions += int((attempt & ~train).sum())
            delivered = attempt & (np.asarray(outage_w) > 0) & ~corrupt
            staleness = np.where(train, 0, self.age)
            agg_w = np.where(delivered, self.cfg.discount(staleness), 0.0)
            return RoundPlan(
                train=train.astype(np.float32), recv=faults.recv.copy(),
                rejoin=faults.rejoin.copy(),
                attempt=attempt.astype(np.float32),
                delivered=delivered.astype(np.float32),
                staleness=staleness.astype(np.int64),
                agg_w=agg_w.astype(np.float32),
                corrupt=corrupt.astype(np.float32))

        # ---- continuous-time round ---------------------------------------
        dl = self.deadline
        if gains is None or fresh_bits is None:
            raise ValueError("deadline mode needs gains= and fresh_bits=")
        n = len(self.valid)
        # a buffered payload can only go back on the air once its backoff
        # window opens inside this round's deadline; fresh uploads replace
        # the pending payload and are never backoff-gated
        start_wait = np.maximum(self.next_try_s - self.now_s, 0.0)
        ready = start_wait < dl.deadline_s
        has_payload = train | (self.valid & ready)
        attempt = (faults.tx > 0) & has_payload
        self.retransmissions += int((attempt & ~train).sum())
        rates = self.arrivals.rates(gains)
        # drawn every round (fixed-size block → the RNG stream stays aligned
        # across the engine, the legacy loop, and checkpoint resume)
        ct = self.arrivals.compute_times(faults.compute_scale)
        bits_on_air = np.where(train, np.asarray(fresh_bits, np.float64),
                               self.bits)
        start = np.where(train, ct, start_wait)
        tx_time = bits_on_air / rates
        arrival = start + tx_time
        ontime = arrival <= dl.deadline_s
        corrupt = np.zeros(n, bool) if faults.corrupt is None \
            else (faults.corrupt > 0)
        corrupt = corrupt & attempt
        clean = attempt & (np.asarray(outage_w) > 0) & ~corrupt
        delivered = clean & ontime
        staleness = np.where(train, 0, self.age)
        disc = self.cfg.discount(staleness)
        agg_w_pre = np.where(clean, disc, 0.0).astype(np.float32)
        agg_w = np.where(delivered, disc, 0.0).astype(np.float32)
        n_del = int(delivered.sum())
        quorum_ok = n_del >= dl.min_quorum
        if not quorum_ok:       # server aborts the round: nothing merges,
            delivered = np.zeros(n, bool)  # deliveries are NACKed back to
            agg_w = np.zeros(n, np.float32)  # pending (no failure counted)
        if math.isinf(dl.deadline_s):
            ok = clean
            sim_dt = float(arrival[ok].max()) if ok.any() else \
                (float(ct[train].max()) if train.any() else 0.0)
        else:
            sim_dt = float(dl.deadline_s)
        return RoundPlan(
            train=train.astype(np.float32), recv=faults.recv.copy(),
            rejoin=faults.rejoin.copy(), attempt=attempt.astype(np.float32),
            delivered=delivered.astype(np.float32),
            staleness=staleness.astype(np.int64), agg_w=agg_w,
            ontime=ontime.astype(np.float32),
            corrupt=corrupt.astype(np.float32), agg_w_pre=agg_w_pre,
            arrival_s=arrival, tx_time_s=tx_time,
            quorum_ok=quorum_ok, n_delivered=n_del, sim_dt_s=sim_dt)

    def end_round(self, plan: RoundPlan,
                  fresh_bits: np.ndarray) -> np.ndarray:
        """Advance the buffer bookkeeping after the round body ran; returns
        the per-client uplink bit charge (0 for silent clients).
        ``fresh_bits`` is the round's encoded payload size per client (only
        read for clients that trained)."""
        train = plan.train > 0
        delivered = plan.delivered > 0
        charged = np.where(plan.attempt > 0,
                           np.where(train, fresh_bits, self.bits), 0.0)
        # training clients overwrite their pending slot with the fresh
        # payload (staleness 0); it clears if it was delivered this round
        self.bits = np.where(train, fresh_bits, self.bits)
        self.age = np.where(train, 0, self.age)
        self.valid = np.where(train, ~delivered, self.valid & ~delivered)
        if self.deadline is not None:
            attempt = plan.attempt > 0
            # channel-caused failures only: a quorum-voided round counts no
            # failures and schedules no backoff (the abort is the server's)
            failed = attempt & ~delivered & plan.quorum_ok
            self.fails = np.where(train, 0, self.fails)   # fresh payload
            self.fails = np.where(failed, self.fails + 1, self.fails)
            self.fails = np.where(delivered, 0, self.fails)
            end_t = self.now_s + plan.sim_dt_s
            wait = self.arrivals.backoff_wait_s(self.fails)
            self.next_try_s = np.where(
                failed, end_t + wait,
                np.where(attempt | train, 0.0, self.next_try_s))
            # abandonment after max_retries failed retransmissions: the
            # payload (and its bit charge) drops out of the ledger for good
            exhausted = self.fails > self.deadline.max_retries
            self.abandoned += int((exhausted & self.valid).sum())
            self.valid &= ~exhausted
            self.bits = np.where(exhausted, 0.0, self.bits)
            self.fails = np.where(exhausted, 0, self.fails)
            self.next_try_s = np.where(exhausted, 0.0, self.next_try_s)
            if not plan.quorum_ok:
                self.quorum_noops += 1
            self.now_s = end_t
        rejoin = plan.rejoin > 0
        self.valid &= ~rejoin                   # crash drops the buffer
        self.fails = np.where(rejoin, 0, self.fails)
        self.next_try_s = np.where(rejoin, 0.0, self.next_try_s)
        return charged

    def counters(self) -> Dict[str, int]:
        """Telemetry snapshot: cumulative run counters + current buffer
        occupancy (feeds the ``staleness`` block of each round event)."""
        return {"pending": int(self.valid.sum()),
                "abandoned": int(self.abandoned),
                "retransmissions": int(self.retransmissions),
                "quorum_noops": int(self.quorum_noops)}

    # ---- checkpoint/resume ------------------------------------------------

    def state_dict(self) -> Dict:
        return {"valid": self.valid.astype(np.int64).tolist(),
                "age": self.age.tolist(), "bits": self.bits.tolist(),
                "fails": self.fails.tolist(),
                "next_try_s": self.next_try_s.tolist(),
                "now_s": self.now_s, "quorum_noops": self.quorum_noops,
                "abandoned": self.abandoned,
                "retransmissions": self.retransmissions}

    def load_state_dict(self, d: Dict) -> None:
        self.valid = np.asarray(d["valid"], np.int64).astype(bool)
        self.age = np.asarray(d["age"], np.int64)
        self.bits = np.asarray(d["bits"], np.float64)
        n = len(self.valid)
        self.fails = np.asarray(d.get("fails", np.zeros(n)), np.int64)
        self.next_try_s = np.asarray(d.get("next_try_s", np.zeros(n)),
                                     np.float64)
        self.now_s = float(d.get("now_s", 0.0))
        self.quorum_noops = int(d.get("quorum_noops", 0))
        self.abandoned = int(d.get("abandoned", 0))
        self.retransmissions = int(d.get("retransmissions", 0))
