"""Host-side runtime for the straggler-tolerant federated round.

The compiled robust round step (``core/cohort.py`` with ``robust=True``)
is deliberately dumb: it consumes per-round fault masks and a pre-computed
aggregation weight vector, and carries the pending-payload buffer.  ALL the
bookkeeping that decides those inputs — which client has a payload on the
air, how stale it is, what the ``α·(1+s)^(-a)`` discount works out to, how
many bits the retransmission charges — is a pure function of host-known
quantities (fault masks + channel outage outcomes), so it lives here, on
the host, where the fused engine and the legacy per-client loop can share
it verbatim.  That sharing is what makes engine-vs-loop parity under
injected faults exact: both paths feed identical weight vectors and ledger
charges from one ``StalenessTracker``.

Per-round contract (both execution paths):

1. ``plan = tracker.begin_round(faults, outage_w)`` — ages the pending
   buffer, drops payloads staler than ``max_staleness``, decides who
   attempts an uplink (``tx`` clients holding a fresh or pending payload),
   who delivers (attempt minus channel outage), and folds the FedAsync
   discount ``α·(1+s)^(-a)`` into ``plan.agg_w``.
2. The round body runs with ``plan.train/agg_w/recv/rejoin``; training
   clients' fresh uploads supersede their pending payloads, stragglers
   retransmit the buffered one.
3. ``charged = tracker.end_round(plan, fresh_bits)`` — updates the buffer
   bookkeeping (fresh-but-undelivered payloads go pending at staleness 0;
   delivered or crash-dropped ones clear) and returns the per-client bit
   charge: fresh encode bits for training clients, the STORED encode bits
   for retransmitters (the payload on the air is the buffered one).

Silent clients (nothing on the air) are excluded from the round's channel
reports entirely — no bytes, no delay, no energy.

Under normalization the global ``α`` cancels out of
``fedavg_stacked``/``masked_fedavg_stacked`` (both divide by the weight
sum), so only the RELATIVE ``(1+s)^(-a)`` discount between fresh and stale
payloads matters; ``α`` is kept for parity with
``core/async_agg.StalenessWeightedAggregator`` and for the all-outage gate
semantics (``α > 0`` never flips the ``Σw > 0`` gate).

With the zero-fault plan every client trains and transmits every round, so
pending payloads are always superseded before they could retransmit,
staleness is identically zero, and ``agg_w`` equals the plain channel
outage weights — the robust round is then bitwise the synchronous round
for ANY ``max_staleness``.  ``max_staleness=0`` additionally makes the
robust engine drop failed uploads exactly like the synchronous engine even
under faults (a pending payload ages to 1 > 0 before its first retransmit
chance).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.wireless.faults import RoundFaults


@dataclasses.dataclass(frozen=True)
class StalenessConfig:
    """Bounded-staleness aggregation knobs (FedAsync-style discounting).

    ``alpha``: global merge weight α (cancels under weight normalization —
    see module docstring).  ``a``: staleness exponent; 0 disables
    discounting (stale payloads merge at full weight).  ``max_staleness``:
    pending payloads older than this many rounds are dropped, not merged;
    0 reproduces the synchronous engine's drop-on-failure semantics."""
    alpha: float = 1.0
    a: float = 0.0
    max_staleness: int = 0

    def discount(self, staleness: np.ndarray) -> np.ndarray:
        return (self.alpha
                * (1.0 + staleness.astype(np.float64)) ** (-self.a)
                ).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One round's resolved schedule (all (n_clients,) arrays)."""
    train: np.ndarray      # float32 — client runs local steps
    recv: np.ndarray       # float32 — client receives the broadcast
    rejoin: np.ndarray     # float32 — crash rejoin (opt state reset)
    attempt: np.ndarray    # float32 — a payload goes on the air
    delivered: np.ndarray  # float32 — attempt survived the channel
    staleness: np.ndarray  # int64   — age of the payload on the air
    agg_w: np.ndarray      # float32 — delivered · α·(1+s)^(-a)


class StalenessTracker:
    """Pending-payload bookkeeping + staleness-discounted weight vector.

    Tracks, per client: whether the pending buffer holds a real payload
    (``valid``), how many rounds old it is (``age``), and the encoded bit
    size it was produced at (``bits`` — what a retransmission charges).
    The payload *contents* live device-side in the engine's pending buffer
    (or the legacy loop's per-client list); the tracker only ever sees
    masks and sizes, which is why both paths can share one instance."""

    def __init__(self, n_clients: int, cfg: Optional[StalenessConfig] = None):
        self.cfg = cfg or StalenessConfig()
        self.valid = np.zeros(n_clients, bool)
        self.age = np.zeros(n_clients, np.int64)
        self.bits = np.zeros(n_clients, np.float64)

    def begin_round(self, faults: RoundFaults,
                    outage_w: np.ndarray) -> RoundPlan:
        """Resolve the round schedule from the fault masks and the realized
        channel outage weights (1.0 delivered / 0.0 outage per client)."""
        # payloads produced in an earlier round are one round staler now;
        # anything beyond the staleness bound is abandoned
        self.age[self.valid] += 1
        self.valid &= self.age <= self.cfg.max_staleness
        train = faults.train > 0
        has_payload = train | self.valid        # fresh upload or buffered
        attempt = (faults.tx > 0) & has_payload
        delivered = attempt & (np.asarray(outage_w) > 0)
        staleness = np.where(train, 0, self.age)
        agg_w = np.where(delivered, self.cfg.discount(staleness), 0.0)
        return RoundPlan(
            train=train.astype(np.float32), recv=faults.recv.copy(),
            rejoin=faults.rejoin.copy(), attempt=attempt.astype(np.float32),
            delivered=delivered.astype(np.float32),
            staleness=staleness.astype(np.int64),
            agg_w=agg_w.astype(np.float32))

    def end_round(self, plan: RoundPlan,
                  fresh_bits: np.ndarray) -> np.ndarray:
        """Advance the buffer bookkeeping after the round body ran; returns
        the per-client uplink bit charge (0 for silent clients).
        ``fresh_bits`` is the round's encoded payload size per client (only
        read for clients that trained)."""
        train = plan.train > 0
        delivered = plan.delivered > 0
        charged = np.where(plan.attempt > 0,
                           np.where(train, fresh_bits, self.bits), 0.0)
        # training clients overwrite their pending slot with the fresh
        # payload (staleness 0); it clears if it was delivered this round
        self.bits = np.where(train, fresh_bits, self.bits)
        self.age = np.where(train, 0, self.age)
        self.valid = np.where(train, ~delivered, self.valid & ~delivered)
        self.valid &= ~(plan.rejoin > 0)        # crash drops the buffer
        return charged

    # ---- checkpoint/resume ------------------------------------------------

    def state_dict(self) -> Dict:
        return {"valid": self.valid.astype(np.int64).tolist(),
                "age": self.age.tolist(), "bits": self.bits.tolist()}

    def load_state_dict(self, d: Dict) -> None:
        self.valid = np.asarray(d["valid"], np.int64).astype(bool)
        self.age = np.asarray(d["age"], np.int64)
        self.bits = np.asarray(d["bits"], np.float64)
