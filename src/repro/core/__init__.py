from repro.core.aggregation import fedavg, partial_fedavg, masked_fedavg  # noqa: F401
from repro.core.rewards import ClientPreference, DoubleReward  # noqa: F401
from repro.core.pftt import PFTTConfig, run_pftt  # noqa: F401
from repro.core.pfit import PFITConfig, run_pfit  # noqa: F401
