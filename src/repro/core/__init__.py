from repro.core.aggregation import (fedavg, fedavg_stacked,  # noqa: F401
                                    masked_fedavg, masked_fedavg_stacked,
                                    partial_fedavg, partial_fedavg_stacked)
from repro.core.cohort import (HostBatchStacker,  # noqa: F401
                               build_cohort_eval, build_ppo_round,
                               build_supervised_round, stack_host_batches)
from repro.core.rewards import ClientPreference, DoubleReward  # noqa: F401
from repro.core.pftt import PFTTConfig, run_pftt  # noqa: F401
from repro.core.pfit import PFITConfig, run_pfit  # noqa: F401
