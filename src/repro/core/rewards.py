"""Double reward model + personalized reward function (paper §IV-C).

Each client holds preference weights (α_help, α_safe); its quality reward is
the linear combination of the two reward models' scores, and the full
personalized reward adds the negative L2 regularization toward the global
model (knowledge-sharing term):

    r_i(x) = α_h^i · r_help(x) + α_s^i · r_safe(x) − λ_i · ‖θ_i − θ_g‖²
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro import trees
from repro.rlhf.reward_model import RewardModel


@dataclasses.dataclass(frozen=True)
class ClientPreference:
    alpha_help: float = 0.5
    alpha_safe: float = 0.5
    lambda_reg: float = 1e-4


@dataclasses.dataclass
class DoubleReward:
    rm_help: RewardModel
    rm_help_params: dict
    rm_safe: RewardModel
    rm_safe_params: dict

    def quality(self, tokens, mask, pref: ClientPreference):
        h = self.rm_help.score(self.rm_help_params, tokens, mask)
        s = self.rm_safe.score(self.rm_safe_params, tokens, mask)
        return pref.alpha_help * h + pref.alpha_safe * s

    def personalized(self, tokens, mask, pref: ClientPreference,
                     local_params: Optional[dict] = None,
                     global_params: Optional[dict] = None):
        r = self.quality(tokens, mask, pref)
        if local_params is not None and global_params is not None \
                and pref.lambda_reg > 0:
            reg = trees.tree_l2(local_params, global_params)
            r = r - pref.lambda_reg * reg
        return r
