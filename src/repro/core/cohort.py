"""Vmapped federated cohort engine — the FL simulation hot path.

The legacy ``run_pfit``/``run_pftt`` loops dispatch O(n_clients ×
local_steps) separate jitted programs per round (one per client per local
step) plus per-client Python aggregation, so wall-clock scales linearly in
cohort size.  The engine instead stacks per-client trainable state along a
leading client axis (``trees.stack``) and compiles ONE fused round step:

    round_step = vmap_over_clients( lax.scan over local steps )   # training
               ∘ stacked aggregation with an outage weight vector  # server
               ∘ (masked) broadcast-back                           # downlink

``donate_argnums`` on the stacked state lets XLA reuse the cohort buffers
round-over-round instead of copying the whole parameter stack.  Per-round
dispatch count is O(1) regardless of cohort size — see
``benchmarks/fl_engine_bench.py`` for the measured looped-vs-fused curve.

Two round builders cover the repo's workloads:

* ``build_supervised_round`` — PFTT-style local SGD (any trainable pytree,
  any upload predicate); also drives PFIT's ``shepherd`` baseline.
* ``build_ppo_round`` — PFIT's personalized-RLHF round: vmapped rollout
  generation, double-reward scoring, PPO updates under per-client gradient
  masks, masked aggregation against the global model, masked broadcast.

Both builders take ``codec=`` (``repro.comms``): the per-client upload is
lossily encoded→decoded (vmapped ``comms.codec.roundtrip``, delta against
the round-input reference) INSIDE the fused step, the server aggregates the
decode, and the step returns the per-client encoded payload bits the round
loop feeds to ``comms.ChannelBudget`` — compression never leaves the
compiled program either.

Outages never leave the compiled program: the wireless layer contributes a
per-client weight *vector* (``RayleighChannel.outage_weights``), zero
entries drop a client from the weighted mean, and an all-zero vector gates
both the global update and the broadcast (clients keep local state), which
reproduces the legacy skip-on-all-outage semantics bit-for-bit.

Both builders take ``robust=True`` (``core/robust.py`` + ``wireless/
faults.py``): the fused step then carries a device-side **pending-update
buffer** (each client's latest produced-but-unmerged upload) and consumes
per-round fault masks — ``train`` (client computed this round), ``recv``
(client gets the broadcast), ``rejoin`` (crash recovery: optimizer state
zeroed) — plus a host-computed **staleness-discounted aggregation weight
vector** (``α·(1+s)^(-a)`` per ``core/robust.StalenessTracker``).  A client
whose uplink failed (channel outage or injected fault) keeps its payload in
the pending buffer and retransmits it next round instead of losing the
work; a straggler's round-``k`` update merges at round ``k+s``.  With
all-ones masks and undiscounted weights the robust body reduces exactly
(bitwise) to the synchronous round.

Both round builders take ``mesh=``/``client_axes=``: the round body is then
wrapped in ``shard_map`` with the stacked client axis sharded over the
given mesh axes (("pod","data") on the production mesh), so ONE fused round
spans every device.  Each shard runs the client-vmap × local-step scan on
its local client slice; the stacked aggregation becomes a ``psum`` of
per-shard weighted partial sums (``aggregation.*_stacked(axis_names=...)``)
and the broadcast-back consumes the replicated global.  Anything without a
client axis — the frozen base, the PPO global model, reward models — stays
replicated (closed-over or ``P()``-specced), so only rank-r LoRA factors /
trainables and optimizer moments pay per-device memory.  Cohorts that do
not divide the shard count are padded with zero-weight **ghost clients**
(``repro.sharding.cohort_sharding``) that the weight vector masks out of
the aggregation exactly.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import trees
from repro.comms import codec as codec_mod
from repro.core.aggregation import (broadcast_merge_stacked,
                                    factored_fedavg_stacked, fedavg_stacked,
                                    masked_fedavg_stacked)
from repro.core.aggregation import _pad_mask
from repro.obs.health import cohort_health
from repro.rlhf.ppo import PPOConfig, make_ppo_fns
from repro.rlhf.rollout import generate
from repro.sharding import client_shard_axes, shard_map


def _where_clients(mask, new, old):
    """Per-client select over stacked trees: leaf ← new where the client's
    ``mask`` entry > 0, else old (leading-axis aligned broadcast)."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(_pad_mask(mask, n.ndim) > 0, n, o), new, old)


def _zero_clients(mask, tree):
    """Zero every leaf row whose client ``mask`` entry > 0 (crash-rejoin
    optimizer reset: adamw moments and step counts re-init to zeros)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.where(_pad_mask(mask, l.ndim) > 0,
                            jnp.zeros_like(l), l), tree)


class HostBatchStacker:
    """Stacks the round's [client][step] host batches into the engine's
    (n_clients, local_steps, …) layout WITHOUT reallocating: the stacked
    numpy buffer is allocated once on the first round and refilled in place,
    then shipped with a single ``jax.device_put`` call per round (one
    transfer per leaf, no per-(client, step) ``np.stack`` garbage).

    Ragged cohorts (clients with unequal per-step batch shapes) are padded
    to the per-leaf maximum and get an extra ``"valid"`` leaf — a
    (n_clients, local_steps, max_batch) float mask with 1.0 on real sample
    rows (every leaf's axis 0 is the sample axis) — so unequal cohorts
    still compile to ONE fused round step.  The loss must weight samples by
    ``batch["valid"]`` (``Model.cls_loss`` does); padded rows then
    contribute exactly zero to loss, gradients, and aggregation, so parity
    with the legacy per-client loop holds.  Uniform cohorts are unchanged:
    no ``"valid"`` leaf, bitwise-identical buffers.

    ``sharding`` (a client-axis ``NamedSharding``, e.g.
    ``CohortSharding.named``): each device receives ONLY its own client
    shard of the host buffer — per-shard slices instead of one replicated
    whole-cohort transfer per device."""

    def __init__(self, sharding: Optional[NamedSharding] = None):
        self._bufs = None
        self._ragged = False
        self._sharding = sharding

    def _scan_shapes(self, per_client_batches):
        first = per_client_batches[0][0]
        shapes = {k: np.shape(v) for k, v in first.items()}
        ragged = False
        for cb in per_client_batches:
            for step in cb:
                for k, v in step.items():
                    if np.shape(v) != shapes[k]:
                        ragged = True
                        shapes[k] = tuple(max(a, b) for a, b in
                                          zip(shapes[k], np.shape(v)))
        return shapes, ragged

    def _alloc(self, per_client_batches, nc, ns):
        first = per_client_batches[0][0]
        shapes, ragged = self._scan_shapes(per_client_batches)
        self._ragged = ragged
        alloc = np.zeros if ragged else np.empty   # pad region stays defined
        self._bufs = {k: alloc((nc, ns) + shapes[k],
                               np.asarray(first[k]).dtype) for k in first}
        if ragged:
            max_b = shapes[next(iter(first))][0]
            self._bufs["valid"] = np.zeros((nc, ns, max_b), np.float32)

    def _compatible(self, per_client_batches, nc, ns):
        """Reusable iff the buffer's (nc, ns) layout matches and every leaf
        still fits: exactly (uniform) or within the padded max (ragged)."""
        ref = {k: v for k, v in self._bufs.items() if k != "valid"}
        if any(v.shape[:2] != (nc, ns) for v in ref.values()):
            return False
        shapes, ragged = self._scan_shapes(per_client_batches)
        if set(shapes) != set(ref):
            return False
        if not self._ragged:
            return not ragged and all(ref[k].shape[2:] == s
                                      for k, s in shapes.items())
        return all(all(d <= bd for d, bd in zip(s, ref[k].shape[2:]))
                   for k, s in shapes.items())

    def __call__(self, per_client_batches):
        nc = len(per_client_batches)
        ns = len(per_client_batches[0])
        if self._bufs is None or not self._compatible(per_client_batches,
                                                      nc, ns):
            # cohorts whose shapes drift (uniform → ragged, a new max batch)
            # pay one realloc; steady-state rounds reuse the buffer
            self._alloc(per_client_batches, nc, ns)
        if self._ragged:
            valid = self._bufs["valid"]
            valid[:] = 0.0
            for ci, cb in enumerate(per_client_batches):
                for si, step in enumerate(cb):
                    n = None
                    for k, v in step.items():
                        v = np.asarray(v)
                        n = v.shape[0] if n is None else n
                        sl = (ci, si) + tuple(slice(0, d) for d in v.shape)
                        self._bufs[k][sl] = v
                    valid[ci, si, :n] = 1.0
        else:
            for ci, cb in enumerate(per_client_batches):
                for si, step in enumerate(cb):
                    for k, v in step.items():
                        self._bufs[k][ci, si] = v
        if self._sharding is None:
            return jax.device_put(self._bufs)
        return jax.device_put(self._bufs, self._sharding)


def stack_host_batches(per_client_batches):
    """[client][step] list of {name: np.ndarray} → one device dict with
    leading (n_clients, local_steps) axes — the engine's data layout.
    One-shot helper; round loops should hold a ``HostBatchStacker`` to
    reuse the host buffer across rounds."""
    return HostBatchStacker()(per_client_batches)


def build_cohort_eval(eval_fn: Callable,
                      sharding: Optional[NamedSharding] = None):
    """Fuse per-client eval into ONE jitted vmapped dispatch per round.

    ``eval_fn(trainable, *per_client_data) -> pytree`` is the UNJITTED
    single-client eval; every argument is stacked on a leading client axis
    (ragged test sets are padded to a common shape with a validity mask —
    the mask rides in as one of the stacked args).  Returns the vmapped
    jitted cohort eval.

    ``sharding`` (client-axis ``NamedSharding``): every stacked input is
    constrained to the client sharding, so GSPMD keeps the vmapped eval
    device-parallel over the mesh instead of gathering the cohort."""
    f = jax.vmap(eval_fn)
    if sharding is None:
        return jax.jit(f)
    spec = tuple(sharding.spec)

    def constrain(x):
        full = P(*(spec + (None,) * (x.ndim - len(spec))))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(sharding.mesh, full))

    def cohort_eval(*args):
        return f(*jax.tree_util.tree_map(constrain, args))

    return jax.jit(cohort_eval)


def build_supervised_round(local_step_fn: Callable,
                           upload_pred: Optional[Callable[[str], bool]] = None,
                           *, donate: bool = True, mesh=None,
                           client_axes=None, codec=None,
                           factored_agg: bool = False,
                           robust: bool = False, min_quorum: int = 0,
                           health: bool = False):
    """Fuse per-client local SGD + FedAvg + broadcast into one jitted step.

    ``local_step_fn(trainable, opt_state, batch) -> (trainable, opt_state,
    loss)`` is the UNJITTED per-client step (the engine owns compilation).
    ``upload_pred`` selects the uploaded/aggregated subtree by path (None →
    the full tree, plain FedAvg).

    Returns ``round_step(stacked_trainable, stacked_opt, batches, weights)``
    where ``batches`` leaves have leading (n_clients, local_steps) axes and
    ``weights`` is the (n_clients,) outage vector.  Produces the updated
    stacked state and the (n_clients, local_steps) loss matrix.

    ``codec`` (a ``repro.comms`` codec): the uploaded subtree is lossily
    encoded→decoded per client INSIDE the fused step (vmapped
    ``comms.codec.roundtrip`` against the round-input reference) before
    aggregation, and the step takes one extra ``keys`` arg ((n, 2) uint32,
    the per-client PRNG keys for stochastic rounding) and returns one extra
    ``payload_bits`` (n,) output — the encoded uplink charge per client.

    ``factored_agg``: aggregate ``{'a','b'}`` LoRA factor pairs as the SVD
    re-projection of the weighted-mean update instead of averaging the
    factors elementwise (``aggregation.factored_fedavg_stacked`` — the
    server never densifies).

    ``mesh`` (+ optional ``client_axes``, default every non-"model" axis):
    wrap the round in ``shard_map`` with the client axis sharded over the
    mesh — each shard trains its local client slice, aggregation is a psum
    of weighted partial sums, and the broadcast-back writes the replicated
    global into every local slot.  Stacked inputs must then be sharded with
    the matching client-axis ``NamedSharding`` and the cohort size must be
    a multiple of the shard count (ghost-pad via ``cohort_sharding``).

    ``robust``: straggler-tolerant signature — ``round_step(st_trainable,
    st_opt, pending, batches, train_m, agg_w, recv_m, rejoin_m, ontime_m
    [, keys])`` → ``(st_trainable, st_opt, pending, losses[, bits])``.
    ``pending`` is the stacked device-side buffer of each client's latest
    produced-but-unmerged upload (uploaded-subtree structure, zeros-init);
    ``train_m``/``recv_m``/``rejoin_m`` are the round's (n,) fault masks
    (``wireless.faults``) and ``agg_w`` is the host-computed
    staleness-discounted aggregation weight vector
    (``core/robust.StalenessTracker``): the server merges ``train`` clients'
    fresh uploads and stragglers' pending payloads in the same weighted
    mean, non-``recv`` clients keep their local shared values, and
    ``rejoin`` clients get zeroed optimizer state.  ``ontime_m`` is the
    continuous-time deadline mask (``wireless/arrivals.py``: 1 = the
    client's upload arrives before the server cutoff) — the body merges
    with ``agg_w · ontime_m``, so a deadline miss keeps the payload in
    ``pending`` at weight 0; all-ones when no deadline is configured.
    ``min_quorum`` (static) generalizes the all-outage gate: a round with
    fewer than ``min_quorum`` positive-weight deliveries is a no-op merge
    (0 keeps the plain ``Σw > 0`` gate).  All-ones masks + undiscounted
    weights reduce bitwise to the synchronous round.

    ``health``: append one extra output — a dict of replicated f32
    training-health scalars (``repro.obs.health.cohort_health``) computed
    inside the same compiled body, so the round still costs exactly one
    dispatch and the factored path is untouched.
    """
    pred = upload_pred or (lambda p: True)
    axes = None if mesh is None else client_shard_axes(mesh, client_axes)
    agg_fn = factored_fedavg_stacked if factored_agg else fedavg_stacked

    def robust_body(st_trainable, st_opt, pending, batches, train_m, agg_w,
                    recv_m, rejoin_m, ontime_m, keys=None):
        # round-input uploaded subtree: the codec's delta reference AND the
        # health scalars' update baseline (send − up_in = this round's delta)
        up_in = (trees.select(st_trainable, pred)
                 if (codec is not None or health) else None)
        ref = up_in if codec is not None else None

        def client(tr, op, client_batches):
            def step(carry, batch):
                tr, op = carry
                tr, op, loss = local_step_fn(tr, op, batch)
                return (tr, op), loss

            (tr, op), losses = jax.lax.scan(step, (tr, op), client_batches)
            return tr, op, losses

        trained_tr, trained_op, losses = jax.vmap(client)(
            st_trainable, st_opt, batches)
        # non-training clients (straggling / crashed / dropped) keep state
        st_trainable = _where_clients(train_m, trained_tr, st_trainable)
        st_opt = _where_clients(train_m, trained_op, st_opt)
        losses = losses * train_m[:, None]

        uploaded = trees.select(st_trainable, pred)
        raw = uploaded if (health and codec is not None) else None
        bits = jnp.zeros_like(agg_w)
        if codec is not None:
            uploaded, bits = jax.vmap(
                lambda k, t, rf: codec_mod.roundtrip(codec, k, t, ref=rf)
            )(keys, uploaded, ref)
        # what goes on the air: a fresh upload supersedes the client's
        # pending payload; stragglers retransmit the pending one
        send = _where_clients(train_m, uploaded, pending)
        # deadline mask: a late arrival merges at weight 0 (it stays in
        # pending and retransmits with its staleness discount next chance)
        agg_w = agg_w * ontime_m
        agg = agg_fn(send, agg_w, axis_names=axes)
        flat_agg = trees.flatten(agg)
        wsum = agg_w.sum()
        n_del = (agg_w > 0).astype(jnp.float32).sum()
        if axes is not None:
            wsum = jax.lax.psum(wsum, axes)
            n_del = jax.lax.psum(n_del, axes)
        # nothing delivered (or an under-quorum cohort) → no-op update
        gate = jnp.logical_and(wsum > 0, n_del >= min_quorum)

        def put(path, loc):
            if path not in flat_agg:
                return loc
            bc = jnp.broadcast_to(flat_agg[path][None].astype(loc.dtype),
                                  loc.shape)
            rm = jnp.broadcast_to(_pad_mask(recv_m, loc.ndim) > 0, loc.shape)
            return jnp.where(jnp.logical_and(gate, rm), bc, loc)

        st_trainable = trees.map_with_path(put, st_trainable)
        st_opt = _zero_clients(rejoin_m, st_opt)   # crash-rejoin: fresh opt
        outs = (st_trainable, st_opt, send, losses)
        if codec is not None:
            outs = outs + (bits,)
        if health:
            outs = outs + (cohort_health(
                send, up_in, losses, agg_w, gate.astype(jnp.float32),
                train_m=train_m, raw=raw,
                decoded=uploaded if codec is not None else None,
                axis_names=axes),)
        return outs

    def round_body(st_trainable, st_opt, batches, weights, keys=None):
        # server-known reference for delta coding: the round-input value of
        # the uploaded subtree (the previous broadcast global on every
        # non-all-outage round); doubles as the health-delta baseline
        up_in = (trees.select(st_trainable, pred)
                 if (codec is not None or health) else None)
        ref = up_in if codec is not None else None

        def client(tr, op, client_batches):
            def step(carry, batch):
                tr, op = carry
                tr, op, loss = local_step_fn(tr, op, batch)
                return (tr, op), loss

            (tr, op), losses = jax.lax.scan(step, (tr, op), client_batches)
            return tr, op, losses

        st_trainable, st_opt, losses = jax.vmap(client)(
            st_trainable, st_opt, batches)

        # server: weighted mean of the uploaded subtree over surviving
        # clients (a psum over the mesh when sharded), broadcast back into
        # every client's stacked slot.  With a codec, the server only ever
        # sees the lossy decode of each client's upload.
        uploaded = trees.select(st_trainable, pred)
        raw = uploaded if (health and codec is not None) else None
        bits = None
        if codec is not None:
            uploaded, bits = jax.vmap(
                lambda k, t, rf: codec_mod.roundtrip(codec, k, t, ref=rf)
            )(keys, uploaded, ref)
        agg = agg_fn(uploaded, weights, axis_names=axes)
        flat_agg = trees.flatten(agg)
        wsum = weights.sum()
        if axes is not None:
            wsum = jax.lax.psum(wsum, axes)
        gate = wsum > 0                    # all-outage round → keep local

        def put(path, loc):
            if path not in flat_agg:
                return loc
            bc = jnp.broadcast_to(flat_agg[path][None].astype(loc.dtype),
                                  loc.shape)
            return jnp.where(gate, bc, loc)

        st_trainable = trees.map_with_path(put, st_trainable)
        outs = (st_trainable, st_opt, losses)
        if codec is not None:
            outs = outs + (bits,)
        if health:
            outs = outs + (cohort_health(
                uploaded, up_in, losses, weights, gate.astype(jnp.float32),
                raw=raw, decoded=uploaded if codec is not None else None,
                axis_names=axes),)
        return outs

    body = robust_body if robust else round_body
    if mesh is None:
        round_step = body
    else:
        # the codec variant carries one extra stacked input (PRNG keys) and
        # one extra stacked output (payload bits); the robust variant adds
        # the pending buffer + three fault masks (all client-sharded);
        # shard_map calls the body positionally so one body serves both
        # arities
        pc = P(axes)
        n_in, n_out = (5, 4) if codec is not None else (4, 3)
        if robust:
            n_in, n_out = n_in + 5, n_out + 1
        # health scalars are psum-ed inside the body → replicated out-spec
        out_specs = (pc,) * n_out + ((P(),) if health else ())
        round_step = shard_map(body, mesh=mesh,
                               in_specs=(pc,) * n_in,
                               out_specs=out_specs, check_vma=False)
    donate_args = ((0, 1, 2) if robust else (0, 1)) if donate else ()
    return jax.jit(round_step, donate_argnums=donate_args)


def build_ppo_round(model, opt, ppo_cfg: PPOConfig, prompt_len: int,
                    gen_len: int, quality_fn: Callable, *,
                    lambda_regs=None,
                    reg_pred: Optional[Callable[[str], bool]] = None,
                    donate: bool = True, mesh=None, client_axes=None,
                    codec=None, robust: bool = False, min_quorum: int = 0):
    """Fuse PFIT's per-client PPO round + masked aggregation + masked
    broadcast into one jitted step.

    ``quality_fn(tokens, resp_mask, alpha_help, alpha_safe)`` scores a
    rollout batch with the personalized double reward (closed over the
    frozen reward-model params).  ``lambda_regs`` is the PER-CLIENT
    (n_clients,) vector of the paper's negative-L2 pull toward the global
    model (None/all-zero skips the reg term entirely); ``reg_pred`` selects
    the regularized subtree.

    Returns ``round_step(st_params, st_opt, global_params, st_masks,
    prompts, keys, alphas_help, alphas_safe, weights)`` →
    ``(st_params, st_opt, new_global, mean_rewards, mean_kls)`` with all
    per-client inputs stacked on a leading client axis.

    ``codec`` (a ``repro.comms`` codec): each client's post-PPO params are
    lossily encoded→decoded (delta against the round-input params, bit
    charge restricted to the client's sparsity-mask entries — unmasked
    parameters are never uploaded) before the masked aggregation, the step
    takes an extra trailing ``keys`` arg ((n, 2) uint32) and returns an
    extra ``payload_bits`` (n,) output.

    ``mesh`` (+ optional ``client_axes``): as in ``build_supervised_round``
    — the whole PPO round runs under ``shard_map`` with per-client state
    sharded over the mesh, the global model replicated (``P()`` in and
    out), and the masked aggregation's numerator/denominator ``psum``ed.
    ``lambda_regs`` must then already cover the ghost-padded cohort.

    ``robust``: straggler-tolerant signature — ``round_step(st_params,
    st_opt, global_params, pending, st_masks, prompts, keys, alphas_help,
    alphas_safe, agg_w, train_m, recv_m, rejoin_m, ontime_m
    [, codec_keys])`` → ``(st_params, st_opt, new_global, pending,
    mean_rewards, mean_kls[, bits])``: same pending-buffer / fault-mask /
    discounted-weight / deadline-mask / ``min_quorum``-gate contract as the
    supervised builder, with the masked aggregation consuming fresh uploads
    and retransmitted pending payloads in one weighted mean and the masked
    broadcast gated per client on ``recv_m``.
    """
    prep, step = make_ppo_fns(model, opt, ppo_cfg, prompt_len)
    reg_pred = reg_pred or (lambda p: p.startswith("stages"))
    lams = None if lambda_regs is None else np.asarray(lambda_regs,
                                                       np.float32)
    use_reg = lams is not None and bool((lams > 0).any())
    axes = None if mesh is None else client_shard_axes(mesh, client_axes)

    def _make_client(global_params):
        def client(params, opt_state, grad_mask, client_prompts, key,
                   a_help, a_safe, lam):
            toks = generate(model, params, client_prompts, gen_len, key,
                            temperature=ppo_cfg.temperature)
            resp = jnp.concatenate(
                [jnp.zeros((toks.shape[0], prompt_len)),
                 jnp.ones((toks.shape[0], gen_len))], axis=1)
            reward = quality_fn(toks, resp, a_help, a_safe)
            if use_reg:
                reg = trees.tree_l2(trees.select(params, reg_pred),
                                    trees.select(global_params, reg_pred))
                reward = reward - lam * reg
            old_logp, adv, ret, resp_mask, mean_kl = prep(
                params, global_params, toks, reward)
            for _ in range(ppo_cfg.ppo_epochs):
                params, opt_state, _, _ = step(
                    params, opt_state, toks, old_logp, adv, ret, resp_mask,
                    grad_mask)
            return params, opt_state, reward.mean(), mean_kl
        return client

    def robust_ppo_body(st_params, st_opt, global_params, pending, st_masks,
                        prompts, keys, alphas_help, alphas_safe, agg_w,
                        train_m, recv_m, rejoin_m, ontime_m, st_lams,
                        codec_keys=None):
        ref = st_params if codec is not None else None   # round-input params
        trained_p, trained_o, mean_rewards, mean_kls = jax.vmap(
            _make_client(global_params))(
            st_params, st_opt, st_masks, prompts, keys, alphas_help,
            alphas_safe, st_lams)
        st_params = _where_clients(train_m, trained_p, st_params)
        st_opt = _where_clients(train_m, trained_o, st_opt)
        mean_rewards = mean_rewards * train_m
        mean_kls = mean_kls * train_m

        uploaded, bits = st_params, jnp.zeros_like(agg_w)
        if codec is not None:
            uploaded, bits = jax.vmap(
                lambda k, t, rf, m: codec_mod.roundtrip(
                    codec, k, t, ref=rf, bit_weights=m)
            )(codec_keys, st_params, ref, st_masks)
        # fresh upload supersedes the pending payload; stragglers/outage
        # clients retransmit the buffered one with its staleness discount;
        # a deadline miss merges at weight 0 (stays pending — see
        # wireless/arrivals.py) and an under-quorum round is a no-op merge
        send = _where_clients(train_m, uploaded, pending)
        agg_w = agg_w * ontime_m
        new_global = masked_fedavg_stacked(global_params, send, st_masks,
                                           agg_w, axis_names=axes)
        wsum = agg_w.sum()
        n_del = (agg_w > 0).astype(jnp.float32).sum()
        if axes is not None:
            wsum = jax.lax.psum(wsum, axes)
            n_del = jax.lax.psum(n_del, axes)
        merged = broadcast_merge_stacked(
            st_params, new_global, st_masks,
            gate=jnp.logical_and(wsum > 0, n_del >= min_quorum))
        st_params = _where_clients(recv_m, merged, st_params)
        st_opt = _zero_clients(rejoin_m, st_opt)   # crash-rejoin: fresh opt
        if codec is not None:
            return (st_params, st_opt, new_global, send, mean_rewards,
                    mean_kls, bits)
        return st_params, st_opt, new_global, send, mean_rewards, mean_kls

    def round_body(st_params, st_opt, global_params, st_masks, prompts, keys,
                   alphas_help, alphas_safe, weights, st_lams,
                   codec_keys=None):
        ref = st_params if codec is not None else None   # round-input params

        st_params, st_opt, mean_rewards, mean_kls = jax.vmap(
            _make_client(global_params))(
            st_params, st_opt, st_masks, prompts, keys, alphas_help,
            alphas_safe, st_lams)

        # server: sparse-mask-weighted aggregation over surviving clients
        # (all-outage → den 0 everywhere → global kept), then each client
        # resumes from the new global on its own masked entries.  With a
        # codec the server aggregates the lossy decode of each client's
        # masked delta upload instead of the exact params.
        uploaded, bits = st_params, None
        if codec is not None:
            uploaded, bits = jax.vmap(
                lambda k, t, rf, m: codec_mod.roundtrip(
                    codec, k, t, ref=rf, bit_weights=m)
            )(codec_keys, st_params, ref, st_masks)
        new_global = masked_fedavg_stacked(global_params, uploaded, st_masks,
                                           weights, axis_names=axes)
        wsum = weights.sum()
        if axes is not None:
            wsum = jax.lax.psum(wsum, axes)
        st_params = broadcast_merge_stacked(st_params, new_global, st_masks,
                                            gate=wsum > 0)
        if codec is not None:
            return st_params, st_opt, new_global, mean_rewards, mean_kls, bits
        return st_params, st_opt, new_global, mean_rewards, mean_kls

    inner = robust_ppo_body if robust else round_body
    if mesh is None:
        body = inner
    else:
        pc, pr = P(axes), P()
        n_extra = 1 if codec is not None else 0
        if robust:
            # pending + three fault masks + agg_w + the deadline mask are
            # client-sharded; the extra `send` output (the next pending
            # buffer) likewise
            in_specs = ((pc, pc, pr, pc, pc, pc, pc, pc, pc, pc, pc, pc, pc,
                         pc, pc) + (pc,) * n_extra)
            out_specs = (pc, pc, pr, pc, pc, pc) + (pc,) * n_extra
        else:
            in_specs = (pc, pc, pr, pc, pc, pc, pc, pc, pc, pc) \
                + (pc,) * n_extra
            out_specs = (pc, pc, pr, pc, pc) + (pc,) * n_extra
        body = shard_map(inner, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    def _st_lams(alphas_help):
        # per-client λ rides in as a stacked arg so the shard_map slices it
        # with the rest of the client axis (a closed-over vector would stay
        # whole-cohort-sized and break the local vmap)
        return (jnp.asarray(lams) if use_reg
                else jnp.zeros_like(alphas_help))

    if robust:
        def round_step(st_params, st_opt, global_params, pending, st_masks,
                       prompts, keys, alphas_help, alphas_safe, agg_w,
                       train_m, recv_m, rejoin_m, ontime_m, codec_keys=None):
            args = (st_params, st_opt, global_params, pending, st_masks,
                    prompts, keys, alphas_help, alphas_safe, agg_w,
                    train_m, recv_m, rejoin_m, ontime_m,
                    _st_lams(alphas_help))
            if codec is not None:
                args = args + (codec_keys,)
            return body(*args)

        donate_args = (0, 1, 3) if donate else ()
    else:
        def round_step(st_params, st_opt, global_params, st_masks, prompts,
                       keys, alphas_help, alphas_safe, weights,
                       codec_keys=None):
            args = (st_params, st_opt, global_params, st_masks, prompts,
                    keys, alphas_help, alphas_safe, weights,
                    _st_lams(alphas_help))
            if codec is not None:
                args = args + (codec_keys,)
            return body(*args)

        donate_args = (0, 1) if donate else ()
    return jax.jit(round_step, donate_argnums=donate_args)
