"""Paper §VI open issues, implemented: asynchronous aggregation with
staleness discounting, fair client selection under fading, and quantized
uplinks.

1. *Wireless Aggregation and Divergence* (§VI-1): "requires asynchronous
   model aggregation strategies and fair client selection mechanisms".
   - ``StalenessWeightedAggregator`` — FedAsync-style server: client updates
     arrive with a round lag (outage → retransmission next round); each is
     merged with weight ``α · (1+staleness)^(-a)`` so stale updates cannot
     drag the global model backwards.
   - ``FairSelector`` — proportional-fairness client scheduling: pick the
     K clients maximizing instantaneous-rate / average-throughput, so deep
     fades don't starve slow clients (classic PF scheduler applied to FL).

2. *Communication Efficiency* (§VI-3): ``quantize_update``/
   ``dequantize_update`` — int8 symmetric per-leaf quantization of uploads
   (4× fewer bytes at f32 training dtypes), with the dequantization error
   small enough that FedAvg convergence is preserved (tests assert both).
   This is the host-side (numpy) legacy path; the jittable codec subsystem
   ``repro.comms`` (stochastic rounding, per-channel scales, entropy bit
   accounting, sketches, SVD factored aggregation) is what the fused cohort
   round runs INSIDE the compiled step — prefer it for new code.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees


# ---------------------------------------------------------------------------
# Staleness-weighted asynchronous aggregation (FedAsync-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StalenessWeightedAggregator:
    """Server state for asynchronous FL: merge each arriving update with
    weight α·(1+staleness)^(-a); updates delayed by outages are buffered and
    merged when they arrive."""

    global_tree: object
    alpha: float = 0.6
    a: float = 0.5
    round: int = 0
    _pending: List = dataclasses.field(default_factory=list)

    def submit(self, client_tree, produced_round: int):
        self._pending.append((client_tree, produced_round))

    def step(self):
        """Advance one server round, merging everything that has arrived.

        The arrivals merge in ONE pass: the global keeps weight
        ``Π(1-wᵢ)`` and the complement goes to the wᵢ-weighted mean of the
        arrivals — permutation-invariant (a sequential pairwise merge would
        give later-submitted updates more influence), and identical to the
        pairwise merge when a single update arrives."""
        if self._pending:
            ws, cs = [], []
            for client_tree, produced in self._pending:
                staleness = max(0, self.round - produced)
                ws.append(self.alpha * (1.0 + staleness) ** (-self.a))
                cs.append(client_tree)
            keep = float(np.prod([1.0 - w for w in ws]))
            wsum = float(sum(ws))
            if wsum > 0:
                def merge(g, *leaves):
                    mean = sum(w * c.astype(jnp.float32)
                               for w, c in zip(ws, leaves)) / wsum
                    return (keep * g.astype(jnp.float32)
                            + (1.0 - keep) * mean).astype(g.dtype)

                self.global_tree = jax.tree_util.tree_map(
                    merge, self.global_tree, *cs)
        self._pending = []
        self.round += 1
        return self.global_tree


# ---------------------------------------------------------------------------
# Proportional-fairness client selection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FairSelector:
    """Select K clients per round by proportional fairness:
    score_i = instantaneous_rate_i / mean_throughput_i.  Clients in deep
    fade are skipped but their average decays, raising future priority."""

    n_clients: int
    ewma: float = 0.9

    def __post_init__(self):
        self._avg = np.ones(self.n_clients)

    def select(self, rates: np.ndarray, k: int) -> List[int]:
        score = rates / np.maximum(self._avg, 1e-9)
        chosen = list(np.argsort(-score)[:k])
        served = np.zeros(self.n_clients)
        served[chosen] = rates[chosen]
        self._avg = self.ewma * self._avg + (1 - self.ewma) * served
        return chosen


# ---------------------------------------------------------------------------
# int8 uplink quantization
# ---------------------------------------------------------------------------


def quantize_update(tree):
    """Per-leaf symmetric int8 quantization → (q_tree, scales dict)."""
    flat = trees.flatten(tree)
    q, scales = {}, {}
    for path, leaf in flat.items():
        if leaf is None:
            q[path] = None
            continue
        x = np.asarray(leaf, np.float32)
        s = float(np.max(np.abs(x))) / 127.0 if x.size else 0.0
        scales[path] = s
        q[path] = (np.round(x / s).astype(np.int8) if s > 0
                   else np.zeros_like(x, np.int8))
    return q, scales


def dequantize_update(q: Dict, scales: Dict, template):
    flat_t = trees.flatten(template)

    def rebuild(path, leaf):
        if leaf is None or q.get(path) is None:
            return leaf
        return jnp.asarray(q[path].astype(np.float32) * scales[path],
                           dtype=leaf.dtype)

    return trees.map_with_path(rebuild, template)


def quantized_bytes(q: Dict) -> int:
    """int8 payload bytes + one f32 scale per leaf that actually ships —
    ``None`` (skipped) paths carry no scale on the wire."""
    shipped = [v for v in q.values() if v is not None]
    return sum(v.size for v in shipped) + 4 * len(shipped)
