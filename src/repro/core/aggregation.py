"""Federated aggregation operators.

* ``fedavg``          — weighted mean of full client trees.
* ``partial_fedavg``  — the paper's PFTT aggregation: only leaves selected by
  a path predicate (the universal adapters) are averaged; everything else
  keeps the global value (local LoRA is never uploaded).
* ``masked_fedavg``   — PFIT's sparse-layer aggregation: elementwise masks
  (last-2-layers × head-sparsity × channel outage) weight each client's
  contribution; where no client contributes, the global value is kept.

On a TPU deployment these are ``psum``s over the ("pod","data") axes — see
``launch/steps.py::make_fl_round_step`` for the collective formulation proven by the dry-run.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees


def fedavg(client_trees: Sequence, weights: Optional[Sequence[float]] = None):
    n = len(client_trees)
    if weights is None:
        weights = [1.0 / n] * n
    w = np.asarray(weights, np.float32)
    w = w / w.sum()

    def avg(*leaves):
        out = leaves[0].astype(jnp.float32) * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            out = out + leaf.astype(jnp.float32) * wi
        return out.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(avg, *client_trees)


def partial_fedavg(global_tree, client_trees: Sequence,
                   pred: Callable[[str], bool],
                   weights: Optional[Sequence[float]] = None):
    """Aggregate only leaves whose path satisfies ``pred``; others keep the
    global value."""
    avg = fedavg(client_trees, weights)
    flat_avg = trees.flatten(avg)

    def pick(path, g):
        return flat_avg[path] if (pred(path) and path in flat_avg) else g

    return trees.map_with_path(pick, global_tree)


def masked_fedavg(global_tree, client_trees: Sequence, masks: Sequence):
    """Elementwise: θ_g ← Σ_i m_i·θ_i / Σ_i m_i, keeping θ_g where Σm = 0.
    ``masks`` are 1/0 float trees (broadcastable to leaves)."""
    def agg(g, *pairs):
        half = len(pairs) // 2
        thetas, ms = pairs[:half], pairs[half:]
        num = jnp.zeros(g.shape, jnp.float32)
        den = jnp.zeros(g.shape, jnp.float32)
        for t, m in zip(thetas, ms):
            mm = jnp.broadcast_to(m.astype(jnp.float32), g.shape)
            num = num + mm * t.astype(jnp.float32)
            den = den + mm
        avg = num / jnp.maximum(den, 1.0)
        return jnp.where(den > 0, avg, g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree_util.tree_map(agg, global_tree, *client_trees, *masks)
