"""Federated aggregation operators.

Two API layers over one math core:

* **Stacked** (the cohort-engine hot path, jit/vmap friendly): client trees
  carry a leading client axis on every leaf and outage/selection is a
  per-client *weight vector* instead of a Python-filtered list —
  ``fedavg_stacked``, ``masked_fedavg_stacked``, ``partial_fedavg_stacked``.
* **List** (legacy convenience API, kept for callers that hold per-client
  trees): ``fedavg``, ``partial_fedavg``, ``masked_fedavg``.  These stack
  their inputs and dispatch to the same stacked core, so both layers are
  bit-identical by construction.

The per-leaf weighted mean is a single ``jnp.tensordot`` over the client
axis (no per-client Python accumulation), with the dtype-preserving cast of
the original implementation.

Every stacked operator takes ``axis_names=``: under the cohort engine's
``shard_map`` (client axis sharded over the ("pod","data") mesh axes) the
weighted mean becomes a per-shard partial sum of weighted client
contributions followed by a ``psum`` over ``axis_names``, with the weight
normalization moved AFTER the collective (each shard only sees its local
slice of the weight vector).  Zero-weight clients — outages and the
engine's ghost padding — drop out of numerator and denominator alike, so
the sharded result matches the single-device math up to summation order.
``launch/steps.py::make_fl_round_step`` is the same formulation stated as
autodiff structure for the dry-run.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import trees


def _client_weights(n: int, weights) -> jnp.ndarray:
    """Normalized (n,) float32 weight vector; uniform when ``weights`` is
    None.  Zero entries model outages; an all-zero vector is the caller's
    signal to keep the previous global (guarded, never a NaN)."""
    if weights is None:
        w = jnp.full((n,), 1.0 / n, jnp.float32)
    else:
        w = jnp.asarray(weights, jnp.float32)
        w = w / jnp.maximum(w.sum(), 1e-12)
    return w


def _weighted_mean(stacked_leaf, w):
    """(n, *S) leaf × (n,) weights → (*S), f32 accumulation, dtype kept."""
    out = jnp.tensordot(w, stacked_leaf.astype(jnp.float32), axes=1)
    return out.astype(stacked_leaf.dtype)


def _pad_mask(m, ndim: int):
    """Right-pad a stacked mask (n, ...) with singleton dims so it broadcasts
    leading-aligned against a stacked leaf of rank ``ndim`` (matches the
    legacy per-client ``broadcast_to(m, leaf.shape)`` semantics)."""
    return m.reshape(m.shape + (1,) * (ndim - m.ndim))


# ---------------------------------------------------------------------------
# stacked API (cohort engine)
# ---------------------------------------------------------------------------


def fedavg_stacked(stacked_tree, weights=None, *, axis_names=None):
    """Weighted mean over the leading client axis of every leaf.

    ``axis_names`` (inside ``shard_map`` only): the client axis is sharded
    over these mesh axes — the per-shard weighted partial sums and the
    weight total are ``psum``ed before normalizing, so every shard returns
    the same replicated global mean."""
    leaves = jax.tree_util.tree_leaves(stacked_tree)
    if not leaves:
        return stacked_tree
    if axis_names is None:
        w = _client_weights(leaves[0].shape[0], weights)
        return jax.tree_util.tree_map(lambda l: _weighted_mean(l, w),
                                      stacked_tree)
    n = leaves[0].shape[0]
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else jnp.asarray(weights, jnp.float32))
    den = jnp.maximum(jax.lax.psum(w.sum(), axis_names), 1e-12)

    def agg(l):
        num = jax.lax.psum(jnp.tensordot(w, l.astype(jnp.float32), axes=1),
                           axis_names)
        return (num / den).astype(l.dtype)

    return jax.tree_util.tree_map(agg, stacked_tree)


def partial_fedavg_stacked(global_tree, stacked_tree,
                           pred: Callable[[str], bool], weights=None, *,
                           axis_names=None):
    """Aggregate only leaves whose path satisfies ``pred``; others keep the
    global value.  ``stacked_tree`` may be a selected subtree (None leaves
    elsewhere) or the full stacked tree."""
    flat_avg = trees.flatten(fedavg_stacked(stacked_tree, weights,
                                            axis_names=axis_names))

    def pick(path, g):
        return flat_avg[path] if (pred(path) and path in flat_avg) else g

    return trees.map_with_path(pick, global_tree)


def masked_fedavg_stacked(global_tree, stacked_tree, stacked_masks,
                          weights=None, *, axis_names=None):
    """Elementwise θ_g ← Σ_i w_i·m_i·θ_i / Σ_i w_i·m_i, keeping θ_g where the
    denominator is zero.  ``stacked_masks`` are 1/0 float trees with the same
    leading client axis (leading-aligned broadcast against each leaf);
    ``weights`` is the outage/selection vector (None → all clients count).
    ``axis_names`` (inside ``shard_map`` only): per-shard numerator and
    denominator partial sums are ``psum``ed over these mesh axes before the
    divide, so the den==0 kept-global semantics are evaluated globally."""
    leaves = jax.tree_util.tree_leaves(stacked_tree)
    n = leaves[0].shape[0]
    if weights is None:
        w = jnp.ones((n,), jnp.float32)
    else:
        w = jnp.asarray(weights, jnp.float32)

    def agg(g, t, m):
        wm = _pad_mask(w, t.ndim) * _pad_mask(m.astype(jnp.float32), t.ndim)
        num = (wm * t.astype(jnp.float32)).sum(0)
        den = jnp.broadcast_to(wm, t.shape).sum(0)
        if axis_names is not None:
            num = jax.lax.psum(num, axis_names)
            den = jax.lax.psum(den, axis_names)
        # guard only the den==0 lanes (kept-global anyway); clamping with
        # maximum(den, 1) would silently mis-scale fractional weights
        avg = num / jnp.where(den > 0, den, 1.0)
        return jnp.where(den > 0, avg, g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree_util.tree_map(agg, global_tree, stacked_tree,
                                  stacked_masks)


def factored_fedavg_stacked(stacked_tree, weights=None, *, axis_names=None,
                            rank=None):
    """LoRA-factor-aware weighted mean: every ``{'a','b'}`` sibling pair in
    the stacked upload tree aggregates as the rank-r SVD re-projection of
    ``Σ ŵ_i A_i·B_i`` (``repro.comms.factored_agg`` — avg(A·B) ≠
    avg(A)·avg(B), and the dense mean update is never materialized); every
    other leaf gets the plain ``fedavg_stacked`` weighted mean.  Same
    ``axis_names`` contract as the other stacked operators (factor slices
    are all-gathered over the client mesh axes — they are rank-r tiny)."""
    from repro.comms.factored_agg import factored_fedavg_tree
    return factored_fedavg_tree(stacked_tree, weights, axis_names=axis_names,
                                rank=rank)


def broadcast_merge_stacked(stacked_tree, global_tree, stacked_masks=None,
                            gate=None):
    """Fused broadcast-back: each client resumes from the global value on its
    masked entries (``m > 0``), keeping local values elsewhere.  With
    ``stacked_masks=None`` every aggregated leaf is overwritten.  ``gate`` is
    an optional scalar (e.g. "any client survived the uplink"); when it is
    falsy the merge is a no-op, mirroring the legacy skip-on-all-outage."""
    def put(loc, glob, m=None):
        bc = jnp.broadcast_to(glob[None].astype(loc.dtype), loc.shape)
        out = bc if m is None else jnp.where(
            jnp.broadcast_to(_pad_mask(m, loc.ndim), loc.shape) > 0, bc, loc)
        if gate is not None:
            out = jnp.where(gate, out, loc)
        return out

    if stacked_masks is None:
        return jax.tree_util.tree_map(put, stacked_tree, global_tree)
    return jax.tree_util.tree_map(put, stacked_tree, global_tree,
                                  stacked_masks)


# ---------------------------------------------------------------------------
# list API (legacy convenience; same core → bit-identical)
# ---------------------------------------------------------------------------


def fedavg(client_trees: Sequence, weights: Optional[Sequence[float]] = None):
    return fedavg_stacked(trees.stack(client_trees), weights)


def partial_fedavg(global_tree, client_trees: Sequence,
                   pred: Callable[[str], bool],
                   weights: Optional[Sequence[float]] = None):
    """Aggregate only leaves whose path satisfies ``pred``; others keep the
    global value."""
    return partial_fedavg_stacked(global_tree, trees.stack(client_trees),
                                  pred, weights)


def masked_fedavg(global_tree, client_trees: Sequence, masks: Sequence):
    """Elementwise: θ_g ← Σ_i m_i·θ_i / Σ_i m_i, keeping θ_g where Σm = 0.
    ``masks`` are 1/0 float trees (broadcastable to leaves).  Masks are
    broadcast trailing-aligned against each leaf (numpy rules) BEFORE
    stacking, so any legacy-legal mask rank is accepted; the stacked API
    expects leading-aligned (n, ...) masks instead."""
    bmasks = [jax.tree_util.tree_map(
        lambda m, t: jnp.broadcast_to(m, t.shape), m, t)
        for m, t in zip(masks, client_trees)]
    return masked_fedavg_stacked(global_tree, trees.stack(client_trees),
                                 trees.stack(bmasks))
