"""PFTT — Personalized Federated Task Tuning (paper §IV-D).

Universal adapters (and the classifier head) are aggregated globally each
round; local LoRA is trained but never uploaded, giving per-client
personalization.  Baselines from the paper's Fig. 5 are method variants:

* ``vanilla_fl`` — adapters + LoRA + head all uploaded and aggregated [1]
* ``fedbert``    — split learning: client trains embeddings + head, the body
                   stays on the server (frozen here); round traffic is the
                   *activation* exchange of split learning [3]
* ``fedlora``    — LoRA-only federated fine-tuning, LoRA aggregated [8]

Every round runs over a simulated Rayleigh uplink (outage → the client's
update is dropped that round) and is logged to a CommLedger (bytes + delay).

Execution goes through the vmapped cohort engine (``core/cohort.py``): one
fused jitted round step (vmap over clients of a scan over local steps +
stacked aggregation + broadcast) instead of O(n_clients × local_steps)
dispatches.  ``PFTTConfig(engine=False)`` keeps the legacy per-client loop
(parity oracle + benchmark baseline).  Ragged cohorts (clients with unequal
batch shapes) are padded and validity-masked by the ``HostBatchStacker``
(the ``"valid"`` sample weights ride the stacked batch into ``cls_loss``),
so they compile to the same single fused step — no legacy fallback.

LoRA executes FACTORED by default (``peft.lora_proj``): the loss threads
the rank-r factor tree next to the params, so under the client-vmap the
frozen base stays unbatched — memory/FLOPs scale as n_clients × rank-r
factors, not n_clients × full weights.  ``PFTTConfig(factored=False)`` is
the merged oracle.  Per-round eval pads every client's test set to one
validity-masked shape and scores the stacked cohort in ONE jitted vmapped
dispatch (``core/cohort.py::build_cohort_eval``).

``PFTTConfig(uplink_codec=...)`` compresses every upload INSIDE the fused
round step (``repro.comms``: stochastic-rounding int8/int4 quantization or
top-k/count-sketch sketching of the delta against the last broadcast
global); the server aggregates the lossy decode and the ledger charges the
encoded payload bits through ``ChannelBudget`` (bits → Rayleigh delay +
transmit energy) instead of the raw ``tree_bytes``.
``PFTTConfig(factored_agg=True)`` aggregates LoRA ``{'a','b'}`` pairs as
the SVD re-projection of the weighted-mean update (never densified) —
see ``repro.comms.factored_agg``.

``run_pftt(cfg, mesh=...)`` shards the fused round across the device mesh:
the stacked client axis is split over the mesh's non-"model" axes via
``shard_map`` (aggregation → psum of weighted partial sums), cohort state
and the round's host batches are placed with a client-axis
``NamedSharding`` (per-shard transfers), and cohorts that don't divide the
shard count are padded with zero-weight ghost clients the aggregation
weight vector masks out.  The frozen base stays replicated; only trainable
state and optimizer moments carry the sharded client axis.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.comms import ChannelBudget, get_codec
from repro.comms import codec as codec_mod
from repro.core.aggregation import (factored_fedavg_stacked, fedavg,
                                    fedavg_stacked)
from repro.core.cohort import (HostBatchStacker, build_cohort_eval,
                               build_supervised_round)
from repro.core.robust import StalenessConfig, StalenessTracker
from repro.configs import get_config
from repro.data.partition import dirichlet_partition
from repro.data.pipeline import batch_iterator
from repro.data.synthetic import ClassificationCorpus
from repro.models import Model
from repro.models import peft as peft_mod
from repro.obs.metrics import RunTelemetry
from repro.obs.trace import SpanTracer, jax_profile_start, jax_profile_stop
from repro.optim import adamw
from repro.sharding import MeshCtx, cohort_sharding
from repro.wireless import (ArrivalModel, CommLedger, DeadlineConfig,
                            FaultPlan, RayleighChannel, tree_bytes)

METHODS = ("pftt", "vanilla_fl", "fedbert", "fedlora")


@dataclasses.dataclass(frozen=True)
class PFTTConfig:
    method: str = "pftt"
    n_clients: int = 4
    rounds: int = 40
    local_steps: int = 10
    batch: int = 16
    seq_len: int = 32
    d_model: int = 128
    lora_rank: int = 8
    adapter_dim: int = 8
    dirichlet_alpha: float = 0.3
    lr: float = 1e-3
    pretrain_steps: int = 200
    pretrain_lr: float = 1e-3
    samples_per_client: int = 400
    test_samples: int = 200
    snr_db: float = 5.0
    seed: int = 0
    verbose: bool = False
    engine: bool = True            # fused vmapped round step (cohort engine)
    factored: bool = True          # unmerged LoRA execution (False → merged
                                   # parity oracle: materialize W + sAB)
    uplink_codec: str = "none"     # none|int8|int4|sketch|countsketch —
                                   # lossy upload compression (repro.comms)
    factored_agg: bool = False     # aggregate LoRA {'a','b'} pairs via SVD
                                   # re-projection (never densified)
    tx_power_w: float = 0.5        # uplink transmit power for the energy
                                   # charge (ChannelBudget)
    fault_plan: Optional[object] = None   # wireless.faults.FaultPlan —
                                   # enables the straggler-tolerant robust
                                   # round (the zero plan is bitwise the
                                   # synchronous engine)
    staleness_alpha: float = 1.0   # FedAsync α (cancels under weight
                                   # normalization — kept for async_agg parity)
    staleness_a: float = 0.0       # staleness exponent a in α·(1+s)^(-a)
    max_staleness: int = 0         # drop pending payloads older than this;
                                   # 0 = sync drop-on-failure semantics
    deadline: Optional[DeadlineConfig] = None  # continuous-time round
                                   # (wireless/arrivals.py): channel-driven
                                   # arrival times, server deadline, retry
                                   # backoff, min_quorum gate; an inert
                                   # config (or None) is bitwise the
                                   # round-granular robust runtime
    ckpt_dir: Optional[str] = None # save the stacked round state per round
                                   # (engine path) for kill + --resume
    resume: bool = False           # restart from ckpt_dir's last round
    population: Optional[object] = None  # fl.population.PopulationConfig —
                                   # population mode: n_clients becomes the
                                   # host-resident population and every
                                   # round samples a cohort_size cohort
                                   # (fused body unchanged; see
                                   # _run_pftt_population)
    telemetry: Optional[object] = None  # repro.obs.TelemetryConfig — JSONL
                                   # round-event stream + host span tracing
                                   # + on-device health scalars (None = off;
                                   # see docs/observability.md)


def _upload_pred(method: str):
    """Which paths are uploaded/aggregated (within the trainable tree)."""
    if method == "pftt":
        return lambda p: p.startswith("shared/")
    if method in ("vanilla_fl", "fedlora", "fedbert"):
        return lambda p: True
    raise ValueError(method)


def _build_trainable(method: str, params, lora):
    """trainable := {'shared': subtree uploaded, 'local': kept on-client}."""
    if method == "pftt":
        shared = trees.select(params, lambda p: peft_mod.is_adapter_path(p)
                              or p.startswith("cls_head"))
        return {"shared": shared, "local": {"lora": lora}}
    if method == "vanilla_fl":
        shared = trees.select(params, lambda p: peft_mod.is_adapter_path(p)
                              or p.startswith("cls_head"))
        return {"shared": {"base": shared, "lora": lora}, "local": {}}
    if method == "fedlora":
        shared = trees.select(params, lambda p: p.startswith("cls_head"))
        return {"shared": {"base": shared, "lora": lora}, "local": {}}
    if method == "fedbert":
        shared = trees.select(params, lambda p: p.startswith(("embed",
                                                              "pos_embed",
                                                              "cls_head")))
        return {"shared": shared, "local": {}}
    raise ValueError(method)


def _split_trainable(method: str, base_params, trainable):
    """(effective params WITHOUT lora merged, unmerged lora tree) — the
    factored-path contract: the base (and non-lora trainables merged into
    it) stays a broadcastable tree under the engine's client-vmap; only the
    returned rank-r factor tree carries the client axis."""
    if method == "pftt":
        return (trees.merge(base_params, trainable["shared"]),
                trainable["local"].get("lora"))
    if method in ("vanilla_fl", "fedlora"):
        return (trees.merge(base_params, trainable["shared"]["base"]),
                trainable["shared"]["lora"])
    if method == "fedbert":
        return trees.merge(base_params, trainable["shared"]), None
    raise ValueError(method)


def _merge_trainable(method: str, base_params, trainable, peft_cfg):
    """Materialize effective params from (frozen base, trainable) — the
    MERGED parity oracle (``PFTTConfig(factored=False)``)."""
    full, lora = _split_trainable(method, base_params, trainable)
    if lora is not None:
        full = peft_mod.apply_lora(full, lora, peft_cfg)
    return full


def _setup_backbone(cfg: PFTTConfig):
    """Shared model setup: reduced roberta, MLM pretrain over all topics,
    PEFT insertion.  Both the cohort path (``run_pftt``) and the population
    path consume it, so their backbones (and the host RNG stream handed
    back) are identical."""
    rng = np.random.RandomState(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    meshctx = MeshCtx.single_device()

    # ---- model: reduced roberta (paper's backbone), pre-trained on IID data
    mcfg = get_config("roberta-base").reduced(d_model=cfg.d_model, repeats=2)
    model = Model(mcfg, meshctx=meshctx)
    base = model.init(key)

    # self-supervised MLM pre-training over ALL topics (like the real
    # RoBERTa); the downstream 4-class task is then learned federated
    pre_corpus = ClassificationCorpus(n_classes=8, seq_len=cfg.seq_len,
                                      seed=cfg.seed, skew=0.8)
    corpus = ClassificationCorpus(seq_len=cfg.seq_len, seed=cfg.seed)
    pre = pre_corpus.sample(2048, rng=rng)
    opt_pre = adamw(cfg.pretrain_lr)
    from repro.data.synthetic import SPECIAL

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def pre_step(params, opt_state, batch):
        def loss_fn(p):
            return model.lm_loss(p, batch)
        loss, g = jax.value_and_grad(loss_fn)(params)
        upd, opt_state = opt_pre.update(g, opt_state, params)
        return trees.tree_add(params, upd), opt_state, loss

    st = opt_pre.init(base)
    it = batch_iterator(pre, cfg.batch, seed=cfg.seed)
    for i in range(cfg.pretrain_steps):
        b = next(it)
        toks = b["tokens"]
        mpos = rng.rand(*toks.shape) < 0.15
        inp = np.where(mpos, SPECIAL["mask"], toks)
        batch = {"tokens": jnp.asarray(inp), "labels": jnp.asarray(toks),
                 "mask": jnp.asarray(mpos.astype(np.float32))}
        base, st, l = pre_step(base, st, batch)
    if cfg.verbose:
        print(f"[pftt:{cfg.method}] MLM pretrain loss {float(l):.3f}")

    # ---- PEFT insertion
    peft_cfg = peft_mod.PEFTConfig(
        lora_rank=cfg.lora_rank, adapter_dim=cfg.adapter_dim,
        lora_targets=("mixer/wq", "mixer/wv"))
    use_adapters = cfg.method in ("pftt", "vanilla_fl")
    use_lora = cfg.method in ("pftt", "vanilla_fl", "fedlora")
    params = peft_mod.init_adapters(key, base, mcfg, peft_cfg) \
        if use_adapters else base
    return model, mcfg, params, peft_cfg, corpus, key, rng, use_lora


def run_pftt(cfg: PFTTConfig, mesh=None, client_axes=None) -> Dict:
    """``mesh`` (optional ``jax.sharding.Mesh``): shard the fused cohort
    round across it — see the module docstring.  ``client_axes`` overrides
    which mesh axes carry the client dim (default: every non-"model" axis).
    Ragged cohorts run the same fused (and sharded) round via
    pad-and-mask.  ``cfg.population`` switches to sampled-cohort population
    mode (``_run_pftt_population``)."""
    assert cfg.method in METHODS, cfg.method
    if cfg.population is not None:
        return _run_pftt_population(cfg, mesh, client_axes)
    model, mcfg, params, peft_cfg, corpus, key, rng, use_lora = \
        _setup_backbone(cfg)

    # ---- non-IID client data (Dirichlet over labels, paper §V-B.2)
    all_data = corpus.sample(cfg.samples_per_client * cfg.n_clients, rng=rng)
    parts = dirichlet_partition(all_data["label"], cfg.n_clients,
                                cfg.dirichlet_alpha, seed=cfg.seed)
    client_train, client_test, client_iters, client_batch_sizes = [], [], [], []
    for ci, idx in enumerate(parts):
        cut = max(1, int(len(idx) * 0.8))
        tr = {k: v[idx[:cut]] for k, v in all_data.items()}
        te = {k: v[idx[cut:]] for k, v in all_data.items()}
        client_train.append(tr)
        client_test.append(te)
        client_batch_sizes.append(min(cfg.batch, max(2, len(idx[:cut]))))
        client_iters.append(batch_iterator(tr, client_batch_sizes[-1],
                                           seed=cfg.seed + ci))

    # ---- per-client trainable state
    opt = adamw(cfg.lr, update_mask=lambda p: not p.endswith("/mask"))
    clients: List[Dict] = []
    for ci in range(cfg.n_clients):
        ck = jax.random.fold_in(key, 100 + ci)
        # "each client incorporates 10-12 local LoRAs based on resources":
        # clients get different numbers of LoRA'd layers / ranks
        lora = peft_mod.init_lora(ck, params, peft_cfg) if use_lora else None
        t = _build_trainable(cfg.method, params, lora)
        clients.append({"trainable": t, "opt_state": opt.init(t)})

    frozen = params
    scale = peft_mod.lora_scale(peft_cfg)

    def _effective(t):
        """(params, lora, lora_scale) per the factored/merged flag."""
        if cfg.factored:
            full, lora = _split_trainable(cfg.method, frozen, t)
            return full, lora, scale
        return _merge_trainable(cfg.method, frozen, t, peft_cfg), None, 1.0

    def local_step(trainable, opt_state, batch):
        def loss_fn(t):
            full, lora, ls = _effective(t)
            return model.cls_loss(full, batch, lora=lora, lora_scale=ls)[0]
        loss, g = jax.value_and_grad(loss_fn)(trainable)
        upd, opt_state = opt.update(g, opt_state, trainable)
        return trees.tree_add(trainable, upd), opt_state, loss

    local_step_jit = jax.jit(local_step)     # legacy per-client path

    # ragged cohorts (unequal client batch sizes) pad-and-mask inside the
    # HostBatchStacker ("valid" sample weights → cls_loss weighted mean), so
    # EVERY cohort compiles to one fused round step.  The sharded engine
    # (mesh=) ghost-pads the cohort to a multiple of the shard count with
    # zero aggregation weight.
    use_engine = cfg.engine
    cs = cohort_sharding(mesh, cfg.n_clients, client_axes) \
        if (mesh is not None and use_engine) else None
    n_rows = cs.total if cs is not None else cfg.n_clients

    # ---- engine-side eval: every client's test set padded to one common
    # shape (validity-masked) and the WHOLE stacked cohort scored in ONE
    # jitted vmapped dispatch per round — O(1) dispatches regardless of
    # cohort size (and no per-test-set-shape retraces).  Ghost rows are
    # all-invalid, so they drop out of the per-client accuracy list.
    max_test = max([len(te["label"]) for te in client_test] + [1])
    seq = client_test[0]["tokens"].shape[1]
    t_toks = np.zeros((n_rows, max_test, seq), np.int32)
    t_labels = np.zeros((n_rows, max_test), np.int32)
    t_valid = np.zeros((n_rows, max_test), np.float32)
    for ci, te in enumerate(client_test):
        n = len(te["label"])
        t_toks[ci, :n] = te["tokens"]
        t_labels[ci, :n] = te["label"]
        t_valid[ci, :n] = 1.0
    _put = (lambda x: jax.device_put(x, cs.named)) if cs is not None \
        else jnp.asarray
    t_toks, t_labels, t_valid = _put(t_toks), _put(t_labels), _put(t_valid)

    def eval_client(trainable, tokens, label, valid):
        full, lora, ls = _effective(trainable)
        hidden, _ = model.forward(full, tokens, lora=lora, lora_scale=ls)
        pred = (hidden[:, 0] @ full["cls_head"]).astype(jnp.float32).argmax(-1)
        correct = (pred == label).astype(jnp.float32) * valid
        return correct.sum(), valid.sum()

    eval_cohort = build_cohort_eval(
        eval_client, sharding=cs.named if cs is not None else None)
    eval_dispatches = [0]

    def eval_round_accs(stacked_trainable):
        """Per-client accuracies — one fused dispatch for the whole cohort
        (clients with an empty test set are dropped, as in the legacy
        per-client loop)."""
        eval_dispatches[0] += 1
        corr, cnt = eval_cohort(stacked_trainable, t_toks, t_labels, t_valid)
        corr, cnt = np.asarray(corr), np.asarray(cnt)
        return [float(c / n) for c, n in zip(corr, cnt) if n > 0]

    channel = RayleighChannel(mean_snr_db=cfg.snr_db, seed=cfg.seed)
    budget = ChannelBudget(channel, tx_power_w=cfg.tx_power_w)
    ledger = CommLedger()
    upload_pred = _upload_pred(cfg.method)
    accs_per_round = []

    # ---- observability (repro.obs): JSONL round events + host span tracer
    # (a disabled tracer still times, it just records nothing) + on-device
    # health scalars riding the fused round outputs (engine path only —
    # they live inside the compiled body, so dispatches/round stays 1)
    tele_cfg = cfg.telemetry
    tracer = SpanTracer(enabled=bool(tele_cfg and tele_cfg.trace))
    tele = RunTelemetry(tele_cfg.out_dir if tele_cfg else None, tracer=tracer)
    health = bool(tele_cfg and tele_cfg.health) and cfg.engine

    # ---- straggler-tolerant runtime (core/robust.py + wireless/faults.py):
    # the fault trace and the staleness tracker are shared verbatim by the
    # engine and the legacy loop, so both paths see identical weights/charges.
    # A non-inert DeadlineConfig switches the tracker to the continuous-time
    # round (wireless/arrivals.py) — with or without an injected fault plan
    dl = cfg.deadline if (cfg.deadline is not None
                          and not cfg.deadline.is_inert()) else None
    robust = cfg.fault_plan is not None or dl is not None
    trace = (cfg.fault_plan or FaultPlan()).realize(
        cfg.n_clients, cfg.rounds) if robust else None
    arrivals = ArrivalModel(channel, dl, cfg.n_clients) \
        if dl is not None else None
    tracker = StalenessTracker(cfg.n_clients, StalenessConfig(
        alpha=cfg.staleness_alpha, a=cfg.staleness_a,
        max_staleness=cfg.max_staleness), deadline=dl,
        arrivals=arrivals) if robust else None
    codec = get_codec(cfg.uplink_codec)
    codec_key = jax.random.fold_in(key, 0x0C0DEC)
    # legacy-loop codec roundtrip (per client; the engine vmaps the same
    # function inside the fused step, so ledgers agree engine-vs-loop)
    rt_jit = None if codec is None else jax.jit(
        lambda k, t, rf: codec_mod.roundtrip(codec, k, t, ref=rf))

    def act_bits() -> float:
        """fedbert split learning: per-step activation exchange dominates —
        uncompressed either way (the codec covers parameter uploads)."""
        if cfg.method != "fedbert":
            return 0.0
        return cfg.local_steps * cfg.batch * cfg.seq_len * cfg.d_model \
            * 4 * 2 * 8

    def payload_bytes(trainable) -> int:
        shared = trees.select(trainable, upload_pred)
        return tree_bytes(shared) + act_bits() / 8

    pending = None
    if use_engine:
        round_step = build_supervised_round(
            local_step, upload_pred,
            mesh=cs.mesh if cs is not None else None,
            client_axes=cs.axes if cs is not None else None,
            codec=codec, factored_agg=cfg.factored_agg, robust=robust,
            min_quorum=(dl.min_quorum if dl is not None else 0),
            health=health)
        pad = cs.pad if cs is not None else (lambda xs: xs)
        cohort_tr = trees.stack(pad([cl["trainable"] for cl in clients]))
        cohort_opt = trees.stack(pad([cl["opt_state"] for cl in clients]))
        if cs is not None:     # client axis over the mesh, base replicated
            cohort_tr = jax.device_put(cohort_tr, cs.named)
            cohort_opt = jax.device_put(cohort_opt, cs.named)
        if robust:             # pending-payload buffer (uploaded subtree)
            pending = jax.tree_util.tree_map(
                jnp.zeros_like, trees.select(cohort_tr, upload_pred))
        payloads = [payload_bytes(cl["trainable"]) for cl in clients]
        stacker = HostBatchStacker(   # host buffer reused round-over-round
            sharding=cs.named if cs is not None else None)
    elif robust:               # legacy-loop pending buffer (parity oracle)
        pending_list = [jax.tree_util.tree_map(
            jnp.zeros_like, trees.select(cl["trainable"], upload_pred))
            for cl in clients]

    # scheduling-size estimate for the continuous-time round (see
    # wireless/arrivals.py): exact for uncompressed uploads; codec fresh
    # uploads reserve the worst-case encoded size until the first realized
    # size replaces it.  The ledger always charges realized bits.
    est_bits = None
    if dl is not None:
        if codec is None:
            est_bits = np.asarray(
                [payload_bytes(cl["trainable"]) * 8 for cl in clients],
                np.float64)
        else:
            est_bits = np.asarray(
                [codec_mod.payload_bits_upper_bound(
                    codec, trees.select(cl["trainable"], upload_pred))
                 + act_bits() for cl in clients], np.float64)

    def _round_reports(rplan, charged, gains):
        """Per-attempt channel reports; deadline mode charges every
        attempt's airtime and books bytes only on delivery."""
        if dl is None:
            return [budget.report(charged[ci], gains[ci])
                    for ci in range(cfg.n_clients) if rplan.attempt[ci] > 0]
        return [budget.attempt_report(
                    charged[ci], gains[ci],
                    tx_time_s=float(rplan.tx_time_s[ci]),
                    arrival_s=float(rplan.arrival_s[ci]),
                    delivered=bool(rplan.delivered[ci] > 0))
                for ci in range(cfg.n_clients) if rplan.attempt[ci] > 0]

    def _vec(v, fill=0.0):
        """Device round vector, ghost-padded with ``fill``."""
        return jax.device_put(cs.pad_vec(v, fill), cs.named) \
            if cs is not None else jnp.asarray(v)

    # ---- round-level checkpoint/resume (engine path): the stacked device
    # state restores exactly; the host RNG streams (channel fading draws,
    # per-client batch iterators) are replayed to the resume point so the
    # continued run is the uninterrupted run
    ckpt_file = meta_file = None
    start_round = 0
    if cfg.ckpt_dir and use_engine:
        ckpt_file = os.path.join(cfg.ckpt_dir, f"pftt_{cfg.method}.npz")
        meta_file = os.path.join(cfg.ckpt_dir, f"pftt_{cfg.method}.json")
        if cfg.resume and os.path.exists(meta_file):
            with open(meta_file) as f:
                meta = json.load(f)
            start_round = int(meta["next_round"])
            accs_per_round[:] = meta["accs_per_round"]
            ledger.rounds[:] = meta["ledger_rounds"]
            tpl = {"trainable": cohort_tr, "opt": cohort_opt}
            if robust:
                tpl["pending"] = pending
                tracker.load_state_dict(meta["tracker"])
                if dl is not None and "est_bits" in meta:
                    est_bits = np.asarray(meta["est_bits"], np.float64)
            state = load_checkpoint(ckpt_file, tpl)
            cohort_tr, cohort_opt = state["trainable"], state["opt"]
            if robust:
                pending = state["pending"]
            if cs is not None:
                cohort_tr = jax.device_put(cohort_tr, cs.named)
                cohort_opt = jax.device_put(cohort_opt, cs.named)
                if robust:
                    pending = jax.device_put(pending, cs.named)
            for _ in range(start_round):        # burn the skipped rounds'
                channel.realize(cfg.n_clients)  # host RNG draws
                if arrivals is not None:
                    arrivals.burn_round()       # compute-time draws
                for ci in range(cfg.n_clients):
                    for _s in range(cfg.local_steps):
                        next(client_iters[ci])

    run_meta = {"mode": "cohort", "method": cfg.method,
                "n_clients": cfg.n_clients, "rounds": cfg.rounds,
                "engine": bool(use_engine), "codec": cfg.uplink_codec}
    if start_round > 0:
        tele.resume(start_round, run_meta)
    else:
        tele.start(run_meta)
    profiling = bool(tele_cfg and tele_cfg.jax_profile) and jax_profile_start(
        os.path.join(tele_cfg.out_dir, "jax_profile"))

    for rnd in range(start_round, cfg.rounds):
        gains = channel.realize(cfg.n_clients)
        rplan = None
        if robust:
            rf = trace.round(rnd)
            gains = gains * rf.gain_scale       # injected SNR dips
            rplan = tracker.begin_round(rf, channel.outage_weights(gains),
                                        gains=gains, fresh_bits=est_bits)
        rnd_key = jax.random.fold_in(codec_key, rnd)
        reports = []
        hstats = None
        if use_engine:
            # host side: draw the round's batches in the legacy (client,
            # step) order into the preallocated stacked buffer, one
            # (per-shard when meshed) device_put, and run ONE compiled
            # round step; ghost clients reuse client 0's batches and get
            # zero aggregation weight
            with tracer.span("gather"):
                batches = stacker(pad(
                    [[next(client_iters[ci])
                      for _ in range(cfg.local_steps)]
                     for ci in range(cfg.n_clients)]))
            # deadline mode hands the engine the pre-deadline weights plus
            # the on-time mask; their product (applied in the fused body)
            # is the pre-quorum agg_w, and the body re-derives the quorum
            # gate so engine and legacy loop agree bit-for-bit
            w = (rplan.agg_w_pre if dl is not None else rplan.agg_w) \
                if robust else channel.outage_weights(gains)
            weights = jax.device_put(cs.pad_weights(w), cs.named) \
                if cs is not None else jnp.asarray(w)
            ck = None
            if codec is not None:
                with tracer.span("encode"):
                    ck = jnp.stack(pad(
                        [jax.random.fold_in(rnd_key, ci)
                         for ci in range(cfg.n_clients)]))
                    if cs is not None:
                        ck = jax.device_put(ck, cs.named)
            if robust:
                # ghosts train + receive like real clients (as in the sync
                # engine) but never rejoin and carry zero agg weight
                ontime = rplan.ontime if dl is not None \
                    else np.ones(cfg.n_clients, np.float32)
                margs = (_vec(rplan.train, 1.0), weights,
                         _vec(rplan.recv, 1.0), _vec(rplan.rejoin, 0.0),
                         _vec(ontime, 1.0))
                if codec is None:
                    with tracer.span("device-step"):
                        outs = round_step(
                            cohort_tr, cohort_opt, pending, batches, *margs)
                    cohort_tr, cohort_opt, pending = outs[:3]
                    fresh = np.asarray([payloads[ci] * 8
                                        for ci in range(cfg.n_clients)])
                else:
                    with tracer.span("device-step"):
                        outs = round_step(cohort_tr, cohort_opt, pending,
                                          batches, *margs, ck)
                    cohort_tr, cohort_opt, pending = outs[:3]
                    eng_bits = outs[4]
                    fresh = (np.asarray(eng_bits, np.float64)[:cfg.n_clients]
                             + act_bits())
                if health:
                    hstats = outs[-1]
                charged = tracker.end_round(rplan, fresh)
                reports = _round_reports(rplan, charged, gains)
            elif codec is None:
                with tracer.span("device-step"):
                    outs = round_step(cohort_tr, cohort_opt, batches,
                                      weights)
                cohort_tr, cohort_opt = outs[:2]
                if health:
                    hstats = outs[-1]
                bits = [payloads[ci] * 8 for ci in range(cfg.n_clients)]
                reports = budget.round_reports(bits, gains)
            else:
                with tracer.span("device-step"):
                    outs = round_step(cohort_tr, cohort_opt, batches,
                                      weights, ck)
                cohort_tr, cohort_opt, eng_bits = outs[0], outs[1], outs[3]
                if health:
                    hstats = outs[-1]
                bits = [float(b) + act_bits()
                        for b in np.asarray(eng_bits)[:cfg.n_clients]]
                reports = budget.round_reports(bits, gains)
        else:
            fresh = np.zeros(cfg.n_clients, np.float64)
            for ci, cl in enumerate(clients):
                # every client draws its round batches even when a fault
                # skips its training — keeps the host data stream aligned
                # with the engine (and with the fault-free run)
                round_batches = [next(client_iters[ci])
                                 for _ in range(cfg.local_steps)]
                if robust and rplan.train[ci] == 0:
                    continue
                ref = (trees.select(cl["trainable"], upload_pred)
                       if codec is not None else None)
                for b_np in round_batches:
                    batch = {k: jnp.asarray(v) for k, v in b_np.items()}
                    cl["trainable"], cl["opt_state"], loss = local_step_jit(
                        cl["trainable"], cl["opt_state"], batch)
                if codec is None:
                    fresh[ci] = payload_bytes(cl["trainable"]) * 8
                else:
                    dec, b = rt_jit(jax.random.fold_in(rnd_key, ci),
                                    trees.select(cl["trainable"],
                                                 upload_pred), ref)
                    cl["decoded_upload"] = dec
                    fresh[ci] = float(b) + act_bits()
                if not robust:
                    reports.append(budget.report(fresh[ci], gains[ci]))
            if robust:
                charged = tracker.end_round(rplan, fresh)
                reports = _round_reports(rplan, charged, gains)
        extra = None
        if dl is not None:
            extra = {"sim_dt_s": float(rplan.sim_dt_s),
                     "quorum_noop": not rplan.quorum_ok,
                     "n_delivered": int(rplan.n_delivered),
                     "corrupt": int(np.asarray(rplan.corrupt).sum())}
            if codec is not None:   # realized encoded size becomes the next
                est_bits = np.where(  # scheduling estimate
                    np.asarray(rplan.train) > 0, fresh, est_bits)
        ledger.log_round(reports, extra, round_id=rnd)

        # --- aggregation over surviving clients (partial for pftt); in the
        # engine path this already happened inside the fused round step.
        # With a codec the server aggregates the lossy decoded uploads.
        if robust and not use_engine:
            # legacy mirror of the robust fused body: same stacked ops, same
            # tracker outputs — fresh uploads supersede pending payloads,
            # stragglers retransmit, recv gates the broadcast, rejoin resets
            # the optimizer
            send_list = [
                (clients[ci]["decoded_upload"] if codec is not None
                 else trees.select(clients[ci]["trainable"], upload_pred))
                if rplan.train[ci] > 0 else pending_list[ci]
                for ci in range(cfg.n_clients)]
            pending_list = send_list
            if float(rplan.agg_w.sum()) > 0:
                st_send = trees.stack(send_list)
                aggw = jnp.asarray(rplan.agg_w)
                agg = (factored_fedavg_stacked(st_send, aggw)
                       if cfg.factored_agg else fedavg_stacked(st_send, aggw))
                for ci, cl in enumerate(clients):
                    if rplan.recv[ci] > 0:
                        cl["trainable"] = trees.merge(cl["trainable"], agg)
            for ci, cl in enumerate(clients):
                if rplan.rejoin[ci] > 0:
                    cl["opt_state"] = jax.tree_util.tree_map(
                        jnp.zeros_like, cl["opt_state"])
        elif not use_engine:
            alive = [ci for ci, r in enumerate(reports) if not r.outage]
            if alive:
                shared_trees = [
                    clients[ci]["decoded_upload"] if codec is not None
                    else trees.select(clients[ci]["trainable"], upload_pred)
                    for ci in alive]
                if cfg.factored_agg:
                    agg = factored_fedavg_stacked(trees.stack(shared_trees))
                else:
                    agg = fedavg(shared_trees)
                for cl in clients:
                    cl["trainable"] = trees.merge(cl["trainable"], agg)

        with tracer.span("eval"):
            accs = eval_round_accs(
                cohort_tr if use_engine
                else trees.stack([cl["trainable"] for cl in clients]))
        accs_per_round.append(float(np.mean(accs)))
        # round event BEFORE the checkpoint (the exactly-once contract:
        # a kill between them re-records the round on resume; a kill after
        # the checkpoint keeps it — resume() drops rounds >= next_round)
        if tele.enabled:
            if rnd == start_round:  # first dispatch of this process paid
                tele.compile_event(  # XLA compilation inside device-step
                    rnd, tracer.totals().get("device-step", 0.0))
            tele.round_event(rnd, {
                "acc": accs_per_round[-1],
                "cohort": None,   # cohort mode: every client, every round
                "comm": {k: v for k, v in ledger.rounds[-1].items()
                         if k != "per_client"},
                "staleness": tracker.counters() if robust else None,
                "health": None if hstats is None else
                {k: float(v) for k, v in hstats.items()},
            }, wall={"phases": tracer.pop_round()})
        if ckpt_file is not None:   # round-level checkpoint (kill-safe)
            with tracer.span("checkpoint"):
                state = {"trainable": cohort_tr, "opt": cohort_opt}
                if robust:
                    state["pending"] = pending
                save_checkpoint(ckpt_file, state)
                meta = {"next_round": rnd + 1,
                        "accs_per_round": accs_per_round,
                        "ledger_rounds": ledger.rounds}
                if robust:
                    meta["tracker"] = tracker.state_dict()
                    if dl is not None:
                        meta["est_bits"] = [float(b) for b in est_bits]
                with open(meta_file, "w") as f:
                    json.dump(meta, f)
            tele.checkpoint(rnd)
        if cfg.verbose and rnd % 5 == 0:
            print(f"[pftt:{cfg.method}] round {rnd} acc {accs_per_round[-1]:.3f} "
                  f"bytes {ledger.rounds[-1]['bytes']:,} "
                  f"outages {ledger.rounds[-1]['outages']}")

    if use_engine:   # sync the per-client dicts once, after the last round
        for cl, tr in zip(clients, trees.unstack(cohort_tr, cfg.n_clients)):
            cl["trainable"] = tr

    if profiling:
        jax_profile_stop()
    tele.close()

    return {
        "method": cfg.method,
        "acc_per_round": accs_per_round,
        "final_acc": accs_per_round[-1],
        "mean_round_bytes": ledger.mean_round_bytes,
        "mean_round_delay_s": ledger.mean_round_delay,
        "total_bytes": ledger.total_bytes,
        "total_energy_j": ledger.total_energy_j,
        "total_sim_time_s": ledger.total_sim_time_s,
        "quorum_noops": ledger.quorum_noops,
        "round_records": ledger.rounds,
        "uplink_codec": cfg.uplink_codec,
        "eval_dispatches_per_round": eval_dispatches[0] / max(cfg.rounds, 1),
        "fused_engine": bool(use_engine),
        "ragged_cohort": len(set(client_batch_sizes)) > 1,
    }


def _run_pftt_population(cfg: PFTTConfig, mesh=None, client_axes=None) -> Dict:
    """Sampled-cohort population mode (``cfg.population``): the host holds
    a ``PopulationStore`` of per-client adapter/opt/pending trees sized to
    ``population`` clients; every round a ``ClientSampler`` draws a
    ``cohort_size`` cohort, the ``PopulationRunner`` gathers the sampled
    rows (overlaying the server's global into the uploaded subtree — the
    downlink), the SAME fused robust round body that a
    ``n_clients=cohort_size`` run compiles executes once, and results
    scatter back.  The ``StalenessTracker`` spans the population, so a
    straggler's pending payload survives rounds it isn't sampled in.
    Non-IID data / availability / mobility come from the
    ``wireless.scenarios.Scenario`` trace; an injected ``FaultPlan`` and a
    ``DeadlineConfig`` compose on top exactly as in cohort mode."""
    from repro.fl.population import (ClientSampler, PopulationData,
                                     PopulationRunner, PopulationStore,
                                     stacked_client_init)
    from repro.wireless.scenarios import Scenario

    pop = cfg.population
    if not cfg.engine:
        raise ValueError("population mode runs the fused engine only "
                         "(PFTTConfig(engine=True))")
    N, K = pop.population, pop.cohort_size
    scen = pop.scenario or Scenario()
    if scen.n_classes != 4:
        raise ValueError("the PFTT classification task is 4-class; "
                         f"scenario has n_classes={scen.n_classes}")
    model, mcfg, params, peft_cfg, corpus, key, rng, use_lora = \
        _setup_backbone(cfg)
    strace = scen.realize(N, cfg.rounds)

    # ---- shared class-bucketed pool; clients draw lazily from their
    # Dirichlet label distribution (no per-client iterator state → nothing
    # to replay on resume)
    pool_n = int(np.clip(cfg.samples_per_client * 16, 1024, 16384))
    pool = corpus.sample(pool_n, rng=rng)
    data = PopulationData(pool, strace.class_probs, seed=cfg.seed)

    # ---- the N-client store: ONE vmapped init over folded keys (constant
    # leaves broadcast), pulled to host numpy
    opt = adamw(cfg.lr, update_mask=lambda p: not p.endswith("/mask"))
    upload_pred = _upload_pred(cfg.method)

    def client_init(ck):
        lora = peft_mod.init_lora(ck, params, peft_cfg) if use_lora else None
        t = _build_trainable(cfg.method, params, lora)
        return {"t": t, "o": opt.init(t)}

    keys = jax.vmap(lambda i: jax.random.fold_in(key, 100 + i))(
        jnp.arange(N))
    stacked = stacked_client_init(client_init, keys)
    pend_np = jax.tree_util.tree_map(
        np.zeros_like, trees.select(stacked["t"], upload_pred))
    store = PopulationStore({"trainable": stacked["t"], "opt": stacked["o"],
                             "pending": pend_np})
    shared0 = trees.select(store.row("trainable", 0), upload_pred)
    global_shared = jax.tree_util.tree_map(np.array, shared0)

    # ---- wireless runtime over the POPULATION (channel draws, fault
    # trace, staleness tracker, optional continuous-time deadline)
    channel = RayleighChannel(mean_snr_db=cfg.snr_db, seed=cfg.seed)
    budget = ChannelBudget(channel, tx_power_w=cfg.tx_power_w)
    ledger = CommLedger()
    dl = cfg.deadline if (cfg.deadline is not None
                          and not cfg.deadline.is_inert()) else None
    trace = (cfg.fault_plan or FaultPlan()).realize(N, cfg.rounds)
    arrivals = ArrivalModel(channel, dl, N) if dl is not None else None
    tracker = StalenessTracker(N, StalenessConfig(
        alpha=cfg.staleness_alpha, a=cfg.staleness_a,
        max_staleness=cfg.max_staleness), deadline=dl, arrivals=arrivals)
    codec = get_codec(cfg.uplink_codec)
    codec_key = None if codec is None else jax.random.fold_in(key, 0x0C0DEC)
    ab = 0.0 if cfg.method != "fedbert" else \
        cfg.local_steps * cfg.batch * cfg.seq_len * cfg.d_model * 4 * 2 * 8
    payload_bits = tree_bytes(shared0) * 8 + ab
    est_bits = None
    if dl is not None:
        est_bits = np.full(N, payload_bits if codec is None else
                           codec_mod.payload_bits_upper_bound(codec, shared0)
                           + ab, np.float64)

    # ---- the fused round body: identical to a cohort_size-client robust
    # run (population mode changes NOTHING below the host orchestration)
    frozen = params
    scale = peft_mod.lora_scale(peft_cfg)

    def _effective(t):
        if cfg.factored:
            full, lora = _split_trainable(cfg.method, frozen, t)
            return full, lora, scale
        return _merge_trainable(cfg.method, frozen, t, peft_cfg), None, 1.0

    def local_step(trainable, opt_state, batch):
        def loss_fn(t):
            full, lora, ls = _effective(t)
            return model.cls_loss(full, batch, lora=lora, lora_scale=ls)[0]
        loss, g = jax.value_and_grad(loss_fn)(trainable)
        upd, opt_state = opt.update(g, opt_state, trainable)
        return trees.tree_add(trainable, upd), opt_state, loss

    # ---- observability: the runner owns the spans (its "round" span is
    # the round_s/host_s accounting); health scalars ride the fused body
    tele_cfg = cfg.telemetry
    tracer = SpanTracer(enabled=bool(tele_cfg and tele_cfg.trace))
    tele = RunTelemetry(tele_cfg.out_dir if tele_cfg else None, tracer=tracer)
    health = bool(tele_cfg and tele_cfg.health)

    cs = cohort_sharding(mesh, K, client_axes) if mesh is not None else None
    round_step = build_supervised_round(
        local_step, upload_pred,
        mesh=cs.mesh if cs is not None else None,
        client_axes=cs.axes if cs is not None else None,
        codec=codec, factored_agg=cfg.factored_agg, robust=True,
        min_quorum=(dl.min_quorum if dl is not None else 0),
        health=health)
    stacker = HostBatchStacker(sharding=cs.named if cs is not None else None)

    runner = PopulationRunner(
        pop=pop, store=store, global_shared=global_shared,
        upload_pred=upload_pred, channel=channel, budget=budget,
        ledger=ledger, tracker=tracker, trace=trace, strace=strace,
        sampler=ClientSampler(pop.sampler, N, K,
                              seed=cfg.seed + 1000 * pop.seed),
        arrivals=arrivals, dl=dl, cs=cs, est_bits=est_bits, act_bits=ab,
        tracer=tracer, health=health)

    # ---- cohort eval: the sampled clients' held-out draws refill one
    # preallocated buffer and score in ONE fused dispatch per round
    n_rows = cs.total if cs is not None else K
    n_eval = int(min(max(cfg.test_samples, 4), 64))
    e_toks = np.zeros((n_rows, n_eval, cfg.seq_len), np.int32)
    e_labels = np.zeros((n_rows, n_eval), np.int32)
    e_valid = np.zeros((n_rows, n_eval), np.float32)
    _put = (lambda x: jax.device_put(x, cs.named)) if cs is not None \
        else jnp.asarray

    def eval_client(trainable, tokens, label, valid):
        full, lora, ls = _effective(trainable)
        hidden, _ = model.forward(full, tokens, lora=lora, lora_scale=ls)
        pred = (hidden[:, 0] @ full["cls_head"]).astype(jnp.float32).argmax(-1)
        correct = (pred == label).astype(jnp.float32) * valid
        return correct.sum(), valid.sum()

    eval_cohort = build_cohort_eval(
        eval_client, sharding=cs.named if cs is not None else None)
    test_cache: Dict[int, Dict] = {}

    def eval_ids(cohort_tr, ids):
        if len(test_cache) > 4096:
            test_cache.clear()
        for j, cid in enumerate(ids):
            te = test_cache.get(int(cid))
            if te is None:
                te = data.test_set(int(cid), n_eval)
                test_cache[int(cid)] = te
            e_toks[j], e_labels[j], e_valid[j] = \
                te["tokens"], te["label"], 1.0
        e_valid[len(ids):] = 0.0
        corr, cnt = eval_cohort(cohort_tr, _put(e_toks), _put(e_labels),
                                _put(e_valid))
        corr, cnt = np.asarray(corr), np.asarray(cnt)
        return [float(c / n)
                for c, n in zip(corr[:len(ids)], cnt[:len(ids)]) if n > 0]

    def draw(cid, rnd):
        return data.round_batches(cid, rnd, cfg.local_steps, cfg.batch)

    # ---- checkpoint/resume: store + global in the npz, sampler RNG /
    # tracker / flags in the JSON sidecar; channel + arrival draws burn
    accs_per_round: List[float] = []
    ckpt_file = meta_file = None
    start_round = 0
    if cfg.ckpt_dir:
        ckpt_file = os.path.join(cfg.ckpt_dir, f"pftt_pop_{cfg.method}.npz")
        meta_file = os.path.join(cfg.ckpt_dir, f"pftt_pop_{cfg.method}.json")
        if cfg.resume and os.path.exists(meta_file):
            with open(meta_file) as f:
                meta = json.load(f)
            start_round = int(meta["next_round"])
            accs_per_round[:] = meta["accs_per_round"]
            ledger.rounds[:] = meta["ledger_rounds"]
            runner.load_state_dict(meta["runner"])
            runner.load_checkpoint_tree(
                load_checkpoint(ckpt_file, runner.checkpoint_tree()))
            runner.burn_rounds(start_round)

    run_meta = {"mode": "population", "method": cfg.method,
                "population": N, "cohort": K, "rounds": cfg.rounds,
                "sampler": pop.sampler, "codec": cfg.uplink_codec}
    if start_round > 0:
        tele.resume(start_round, run_meta)
    else:
        tele.start(run_meta)
    profiling = bool(tele_cfg and tele_cfg.jax_profile) and jax_profile_start(
        os.path.join(tele_cfg.out_dir, "jax_profile"))

    for rnd in range(start_round, cfg.rounds):
        out = runner.run_round(rnd, round_step=round_step, stacker=stacker,
                               draw_batches=draw,
                               local_steps=cfg.local_steps,
                               payload_bits=payload_bits,
                               codec_key=codec_key)
        with tracer.span("eval"):
            accs = eval_ids(out["cohort_tr"], out["ids"])
        accs_per_round.append(float(np.mean(accs)) if accs else 0.0)
        # round event BEFORE the checkpoint — see run_pftt (the same
        # exactly-once resume ordering)
        if tele.enabled:
            if rnd == start_round:
                tele.compile_event(
                    rnd, tracer.totals().get("device-step", 0.0))
            tele.round_event(rnd, {
                "acc": accs_per_round[-1],
                "cohort": [int(i) for i in out["ids"]],
                "comm": {k: v for k, v in ledger.rounds[-1].items()
                         if k != "per_client"},
                "staleness": tracker.counters(),
                "health": out["health"],
            }, wall={"phases": tracer.pop_round()})
        if ckpt_file is not None:
            with tracer.span("checkpoint"):
                save_checkpoint(ckpt_file, runner.checkpoint_tree())
                meta = {"next_round": rnd + 1,
                        "accs_per_round": accs_per_round,
                        "ledger_rounds": ledger.rounds,
                        "runner": runner.state_dict()}
                with open(meta_file, "w") as f:
                    json.dump(meta, f)
            tele.checkpoint(rnd)
        if cfg.verbose and rnd % 5 == 0:
            print(f"[pftt-pop:{cfg.method}] round {rnd} "
                  f"cohort acc {accs_per_round[-1]:.3f} "
                  f"sampled {sorted(int(i) for i in out['ids'])[:8]}… "
                  f"host {runner.host_overhead_frac:.1%}")

    if profiling:
        jax_profile_stop()
    tele.close()

    return {
        "method": cfg.method,
        "acc_per_round": accs_per_round,
        "final_acc": accs_per_round[-1] if accs_per_round else 0.0,
        "mean_round_bytes": ledger.mean_round_bytes,
        "mean_round_delay_s": ledger.mean_round_delay,
        "total_bytes": ledger.total_bytes,
        "total_energy_j": ledger.total_energy_j,
        "total_sim_time_s": ledger.total_sim_time_s,
        "quorum_noops": ledger.quorum_noops,
        "round_records": ledger.rounds,
        "uplink_codec": cfg.uplink_codec,
        "fused_engine": True,
        "population": N,
        "cohort_size": K,
        "sampler": pop.sampler,
        "scenario": scen.to_dict(),
        "participation_frac": float(runner.seen.mean()),
        "host_overhead_frac": runner.host_overhead_frac,
        "host_s": runner.host_s,
        "round_s": runner.round_s,
        "round_wall": list(runner.round_wall),
        "store_bytes": store.nbytes(),
    }
