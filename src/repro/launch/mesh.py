"""Production mesh construction.

Target: TPU v5e, 256 chips/pod.  Single-pod mesh is (16 data × 16 model);
multi-pod adds a leading pod axis (2 × 16 × 16 = 512 chips).  Defined as
functions so importing this module never touches jax device state — only
``launch/dryrun.py`` (which sets the host-device-count flag first) or a real
TPU launcher should call these.
"""
from __future__ import annotations

import jax

from repro.sharding import MeshCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "did you set --xla_force_host_platform_device_count?")
    return jax.make_mesh(shape, axes, devices=devices)


def make_meshctx(*, multi_pod: bool = False) -> MeshCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return MeshCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model")


# Hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
