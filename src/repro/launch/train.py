"""Training launcher: runs real steps on the available devices (CPU here,
TPU pod in production — the same pjit program the dry-run compiles).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50 --batch 8 --seq 128

``--fl-clients N`` instead runs the federated cohort engine with the
stacked client axis sharded over every available device (``shard_map``
round, psum aggregation — core/cohort.py).  ``--arch roberta-base`` runs
PFTT's reduced-roberta classification cohort (``--steps``/``--seq`` don't
apply; ``--batch``/``--lr``/``--fl-rounds`` do):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.train --arch roberta-base --fl-clients 8 \
        --fl-rounds 3

``--population N --cohort K`` (roberta-base) switches PFTT to
population mode: the host holds N clients' adapter/opt trees
(``fl.population.PopulationStore``) and every round a seeded sampler
draws a K-client cohort into the SAME fused round the ``--fl-clients K``
run compiles.  ``--scenario`` adds non-IID data / availability /
mobility (``wireless.scenarios``); fault plans, deadlines, codecs, and
checkpointing compose unchanged:

    PYTHONPATH=src python -m repro.launch.train --arch roberta-base \
        --population 256 --cohort 8 --fl-rounds 2 \
        --scenario alpha=0.1,avail=diurnal --sampler availability

Any other ``--arch`` runs the universal fused round on that architecture
(``core/arch_round.py``): a ragged LoRA cohort trained through ONE fused
dispatch per round with the frozen base replicated and only the rank-r
factors batched.  ``--assert-fused`` turns the run into the CI arch-matrix
check — it fails unless zero dense merges were traced, each round was one
dispatch, and the losses match the legacy dense-merge oracle to ≤1e-5:

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-v2-236b \
        --fl-clients 4 --fl-rounds 2 --assert-fused
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.sharding import MeshCtx, batch_specs, param_specs, use_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-parallel axis size (0 → n_devices)")
    ap.add_argument("--fl-clients", type=int, default=0,
                    help="run a federated PFTT cohort of this size with the "
                         "client axis sharded over all devices (0 → off)")
    ap.add_argument("--fl-rounds", type=int, default=3)
    ap.add_argument("--uplink-codec", default="none",
                    choices=["none", "int8", "int4", "sketch"],
                    help="compress FL uploads inside the fused round step "
                         "(repro.comms): stochastic-rounding int8/int4 "
                         "quantization or top-k sketching of the delta "
                         "against the last broadcast global")
    ap.add_argument("--factored-agg", action="store_true",
                    help="aggregate LoRA factor pairs via SVD re-projection "
                         "of the weighted-mean update (never densified)")
    ap.add_argument("--fault-plan", default=None,
                    help="inject wireless faults into the FL run: 'k=v,...' "
                         "(dropout_p/straggle_p/crash_p/snr_dip_p/corrupt_p/"
                         "seed/...) or a JSON file path "
                         "(wireless.faults.FaultPlan)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="continuous-time FL round: server closes the round "
                         "this many simulated seconds after dispatch; late "
                         "arrivals buffer as stale retransmissions "
                         "(wireless.arrivals.DeadlineConfig)")
    ap.add_argument("--backoff-base-s", type=float, default=0.0,
                    help="retransmission backoff base: the n-th failure of "
                         "a payload waits base*2^(n-1) simulated seconds")
    ap.add_argument("--max-retries", type=int, default=8,
                    help="abandon a pending payload after this many failed "
                         "retransmissions")
    ap.add_argument("--min-quorum", type=int, default=0,
                    help="void the round (no merge, deliveries NACKed back "
                         "to pending) when fewer payloads arrive in time")
    ap.add_argument("--compute-time-s", type=float, default=0.0,
                    help="mean per-round local compute time before a fresh "
                         "upload starts transmitting (stragglers scale it)")
    ap.add_argument("--staleness-a", type=float, default=0.0,
                    help="staleness discount exponent: late uploads merge "
                         "with weight α·(1+s)^(-a)")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="retransmit failed uploads for up to this many "
                         "rounds (0 = synchronous drop-on-failure)")
    ap.add_argument("--population", type=int, default=0,
                    help="population mode (roberta-base): the host holds "
                         "this many clients' adapter/opt trees and every "
                         "round samples a --cohort cohort into the fused "
                         "round (fl.population; 0 → off)")
    ap.add_argument("--cohort", type=int, default=8,
                    help="population mode: sampled cohort size per round")
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "availability"],
                    help="population mode: per-round client sampler "
                         "(availability weights by the scenario's "
                         "avail_p trace)")
    ap.add_argument("--scenario", default=None,
                    help="population scenario spec: 'k=v,...' "
                         "(alpha/avail/avail_period/mobility/seed/... — "
                         "wireless.scenarios.Scenario.from_spec) or a JSON "
                         "file path")
    ap.add_argument("--ckpt-dir", default=None,
                    help="FL engine: save the stacked round state each round "
                         "here so a killed run can --resume")
    ap.add_argument("--resume", action="store_true",
                    help="FL engine: restart from --ckpt-dir's last round")
    ap.add_argument("--assert-fused", action="store_true",
                    help="FL engine: fail unless the run took the fused "
                         "factored path — zero dense merges, one dispatch "
                         "per round, and (non-roberta archs) ≤1e-5 parity "
                         "vs the legacy dense-merge oracle")
    ap.add_argument("--telemetry-dir", default=None,
                    help="FL runs: write the structured run telemetry "
                         "(events.jsonl — schema-versioned round metrics "
                         "joining eval, comm ledger, staleness and health "
                         "signals; repro.obs) into this directory")
    ap.add_argument("--trace", action="store_true",
                    help="with --telemetry-dir: also write trace.json, a "
                         "Chrome trace-event file of the host round phases "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--jax-profile", action="store_true",
                    help="with --telemetry-dir: bracket the run in a "
                         "jax.profiler trace under <dir>/jax_profile")
    ap.add_argument("--fl-seq", type=int, default=16,
                    help="arch FL round: per-sample sequence length")
    ap.add_argument("--fl-dmodel", type=int, default=64,
                    help="arch FL round: reduced-config width")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if args.population and args.arch != "roberta-base":
        raise SystemExit("--population runs the PFTT workload: "
                         "use --arch roberta-base")
    if args.fl_clients and args.arch != "roberta-base":
        from repro.core.arch_round import ArchRoundConfig, run_arch_round
        print(f"universal fused round: --arch {args.arch}, "
              f"{args.fl_clients} clients on {n_dev} device(s)")
        mesh = jax.make_mesh((n_dev,), ("data",))
        cfg = ArchRoundConfig(arch=args.arch, n_clients=args.fl_clients,
                              rounds=args.fl_rounds,
                              batch=min(args.batch, 4), seq_len=args.fl_seq,
                              d_model=args.fl_dmodel, lr=args.lr,
                              oracle=args.assert_fused)
        res = run_arch_round(cfg, mesh=mesh, client_axes=("data",))
        print(f"arch={res['arch']} targets={res['lora_targets']} "
              f"ragged={res['ragged']} ghosts={res['n_ghosts']} "
              f"dispatches/round={res['dispatches_per_round']} "
              f"dense_merges={res['dense_merges_in_engine']} "
              f"loss/round={['%.4f' % l for l in res['loss_per_round']]}")
        if args.assert_fused:
            err = res["oracle_loss_max_err"]
            print(f"oracle parity max err {err:.2e}")
            assert res["dense_merges_in_engine"] == 0, \
                "dense-merge fallback taken inside the fused round"
            assert res["dispatches_per_round"] == 1.0, \
                "cohort fell back to per-client dispatch"
            assert err <= 1e-5, f"factored/oracle divergence {err:.2e}"
            print("fused path asserted: factored, one dispatch, "
                  "oracle parity OK")
        return
    telemetry = None
    if args.telemetry_dir:
        from repro.obs import TelemetryConfig
        telemetry = TelemetryConfig(out_dir=args.telemetry_dir,
                                    trace=args.trace,
                                    jax_profile=args.jax_profile)
    if args.fl_clients or args.population:
        import math

        from repro.core.pftt import PFTTConfig, run_pftt
        from repro.wireless import DeadlineConfig, FaultPlan
        deadline = None
        if (args.deadline_s is not None or args.backoff_base_s > 0
                or args.min_quorum > 0 or args.compute_time_s > 0):
            deadline = DeadlineConfig(
                deadline_s=(args.deadline_s if args.deadline_s is not None
                            else math.inf),
                backoff_base_s=args.backoff_base_s,
                max_retries=args.max_retries, min_quorum=args.min_quorum,
                compute_mean_s=args.compute_time_s)
        population = None
        if args.population:
            from repro.fl.population import PopulationConfig
            from repro.wireless.scenarios import Scenario
            population = PopulationConfig(
                population=args.population, cohort_size=args.cohort,
                sampler=args.sampler,
                scenario=Scenario.from_spec(args.scenario))
            print(f"population PFTT: {args.population} clients, "
                  f"cohort {args.cohort}/round ({args.sampler} sampling) "
                  f"on {n_dev} device(s)")
        else:
            print(f"federated cohort demo (PFTT reduced-roberta workload; "
                  f"--steps/--seq ignored) on {n_dev} device(s)")
        mesh = jax.make_mesh((n_dev,), ("data",))
        cfg = PFTTConfig(n_clients=args.fl_clients or args.cohort,
                         rounds=args.fl_rounds,
                         batch=args.batch, lr=args.lr, local_steps=5,
                         pretrain_steps=50, samples_per_client=200,
                         uplink_codec=args.uplink_codec,
                         factored_agg=args.factored_agg,
                         fault_plan=FaultPlan.from_spec(args.fault_plan),
                         staleness_a=args.staleness_a,
                         max_staleness=args.max_staleness,
                         deadline=deadline, population=population,
                         ckpt_dir=args.ckpt_dir, resume=args.resume,
                         telemetry=telemetry, verbose=True)
        res = run_pftt(cfg, mesh=mesh, client_axes=("data",))
        print(f"sharded cohort over {n_dev} device(s): final acc "
              f"{res['final_acc']:.3f} mean round bytes "
              f"{res['mean_round_bytes']:,.0f} "
              f"(codec={args.uplink_codec}) mean round delay "
              f"{res['mean_round_delay_s']:.3f}s energy "
              f"{res['total_energy_j']:.2f}J")
        if population is not None:
            print(f"population: sampled {res['participation_frac']:.1%} of "
                  f"{res['population']} clients, host overhead "
                  f"{res['host_overhead_frac']:.1%} of round wall-clock, "
                  f"store {res['store_bytes'] / 1e6:.1f}MB")
        if deadline is not None:
            print(f"continuous-time round: sim time "
                  f"{res['total_sim_time_s']:.1f}s quorum no-ops "
                  f"{res['quorum_noops']}")
        if args.assert_fused:
            assert res["fused_engine"], "PFTT ran the legacy per-client loop"
            print("fused path asserted: engine round")
        return
    d = args.data_axis or n_dev
    mesh = jax.make_mesh((d, n_dev // d), ("data", "model"))
    meshctx = MeshCtx(mesh=mesh)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, meshctx=meshctx, remat=True)
    key = jax.random.PRNGKey(0)
    params = model.init(key, max_seq=args.seq)
    step_fn, opt = make_train_step(model, lr=args.lr)
    opt_state = opt.init(params)

    pspecs = param_specs(meshctx, jax.eval_shape(lambda: params), cfg)
    params = jax.device_put(params, jax.tree.map(
        meshctx.sharding, pspecs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))

    rng = np.random.RandomState(0)
    jstep = jax.jit(step_fn)
    t0 = time.time()
    with use_mesh(mesh):
        for i in range(args.steps):
            toks = jnp.asarray(rng.randint(6, cfg.vocab_size,
                                           size=(args.batch, args.seq + 1)))
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                     "mask": jnp.ones((args.batch, args.seq))}
            if cfg.is_encoder_decoder:
                batch["frames"] = jnp.asarray(rng.randn(
                    args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
            if cfg.n_prefix_tokens:
                batch["patches"] = jnp.asarray(rng.randn(
                    args.batch, cfg.n_prefix_tokens, cfg.prefix_dim),
                    jnp.float32)
            params, opt_state, loss = jstep(params, opt_state, batch)
            if i % 10 == 0:
                print(f"step {i:4d} loss {float(loss):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt, params)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
