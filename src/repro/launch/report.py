"""Render a run-telemetry directory (``--telemetry-dir``) as a report.

    PYTHONPATH=src python -m repro.launch.report /tmp/telemetry

prints a per-round table (metric, comm bytes/delay/outages, staleness
counters, health scalars, host phase timings) from ``events.jsonl`` plus
a slowest-span summary (total host seconds per phase across the run, and
the single slowest round for each phase).  ``--check`` validates the
event stream against the schema (``repro.obs.validate_events``) and
exits nonzero on any violation — the CI telemetry cell runs it after a
``--telemetry-dir`` training run.
"""
import argparse
import os
import sys

from repro.obs import read_events, validate_events


def _fmt(v, width=9):
    if v is None:
        return "-".rjust(width)
    if isinstance(v, float):
        return f"{v:.4g}".rjust(width)
    return str(v).rjust(width)


def _wall_s(phases):
    # the "round" span (population runner) already contains
    # sample/gather/device-step/scatter/ledger — don't double-count the
    # nested children; eval runs outside it
    if "round" in phases:
        return phases["round"] + phases.get("eval", 0.0) \
            + phases.get("checkpoint", 0.0)
    return sum(phases.values())


def _metric_key(rounds):
    for k in ("acc", "reward", "eval_loss"):
        if rounds and k in rounds[0]:
            return k
    return None


def round_table(rounds):
    lines = []
    mk = _metric_key(rounds)
    head = (f"{'round':>5} {mk or 'metric':>9} {'bytes':>12} {'delay_s':>9} "
            f"{'outages':>7} {'pending':>7} {'retx':>6} {'health:loss':>11} "
            f"{'upd_norm':>9} {'host_s':>8}")
    lines.append(head)
    lines.append("-" * len(head))
    for e in rounds:
        comm = e.get("comm") or {}
        st = e.get("staleness") or {}
        h = e.get("health") or {}
        phases = (e.get("wall") or {}).get("phases") or {}
        lines.append(
            f"{e['round']:>5} {_fmt(e.get(mk))} "
            f"{_fmt(comm.get('bytes'), 12)} {_fmt(comm.get('delay_s'))} "
            f"{_fmt(comm.get('outages'), 7)} {_fmt(st.get('pending'), 7)} "
            f"{_fmt(st.get('retransmissions'), 6)} "
            f"{_fmt(h.get('loss_mean'), 11)} {_fmt(h.get('update_norm'))} "
            f"{_fmt(_wall_s(phases), 8)}")
    return "\n".join(lines)


def span_summary(rounds):
    totals, worst = {}, {}
    for e in rounds:
        for name, dur in ((e.get("wall") or {}).get("phases") or {}).items():
            totals[name] = totals.get(name, 0.0) + dur
            if name not in worst or dur > worst[name][1]:
                worst[name] = (e["round"], dur)
    if not totals:
        return "(no phase timings recorded)"
    lines = [f"{'phase':>12} {'total_s':>9} {'slowest_round':>13} "
             f"{'slowest_s':>9}"]
    lines.append("-" * len(lines[0]))
    for name, tot in sorted(totals.items(), key=lambda kv: -kv[1]):
        rnd, dur = worst[name]
        lines.append(f"{name:>12} {tot:>9.4f} {rnd:>13} {dur:>9.4f}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("telemetry_dir",
                    help="directory holding events.jsonl (a training run's "
                         "--telemetry-dir)")
    ap.add_argument("--check", action="store_true",
                    help="validate the event stream against the schema and "
                         "exit nonzero on any violation")
    args = ap.parse_args(argv)

    path = os.path.join(args.telemetry_dir, "events.jsonl")
    if not os.path.exists(path):
        print(f"report: no events.jsonl under {args.telemetry_dir}",
              file=sys.stderr)
        return 2
    events = read_events(path)
    errors = validate_events(events)

    run = next((e for e in events if e.get("event") == "run"), None)
    rounds = [e for e in events if e.get("event") == "round"]
    resumes = sum(1 for e in events if e.get("event") == "resume")
    ckpts = sum(1 for e in events if e.get("event") == "checkpoint")

    if run is not None:
        meta = ", ".join(f"{k}={v}" for k, v in
                         sorted((run.get("meta") or {}).items()))
        print(f"run: schema v{run.get('schema')} ({meta})")
    print(f"{len(rounds)} round(s), {ckpts} checkpoint(s), "
          f"{resumes} resume(s)\n")
    print(round_table(rounds))
    print("\nhost spans (slowest first):")
    print(span_summary(rounds))

    if args.check:
        if errors:
            print(f"\ncheck FAILED: {len(errors)} schema violation(s)",
                  file=sys.stderr)
            for err in errors:
                print(f"  - {err}", file=sys.stderr)
            return 1
        print(f"\ncheck OK: {len(events)} events, schema valid")
    elif errors:
        print(f"\nwarning: {len(errors)} schema violation(s) "
              f"(run with --check to fail on them)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
