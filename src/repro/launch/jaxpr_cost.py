"""Exact FLOP counting by walking the jaxpr.

``compiled.cost_analysis()`` counts while-loop (scan) bodies ONCE, so any
scanned-layer model is undercounted by ~n_layers.  This walker recurses into
scan (×length), shard_map (×mesh size — body shapes are per-device), remat,
pjit and custom-vjp calls, so remat recompute and per-layer work are counted
exactly.  Shapes in the jaxpr are GLOBAL (pre-SPMD): divide by chip count
for the per-device roofline term (assumes parallel efficiency 1; the gap to
the compiled HLO is part of the analysis).

Matmul flops: dot_general = 2·M·N·K (batched dims multiply).  Elementwise /
reduction ops are counted at 1 flop per output element — they are noise next
to the GEMMs but keep softmax/norm-heavy graphs honest.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np

_ELEMWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "neg", "abs", "sign", "floor", "ceil",
    "erf", "integer_pow", "pow", "select_n", "clamp", "cumsum", "cumlogsumexp",
    "reduce_sum", "reduce_max", "reduce_min", "reduce_and", "reduce_or",
    "argmax", "argmin", "logsumexp", "softmax",
}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _dot_flops(eqn) -> int:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    m = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                  if i not in lc and i not in lb) if lhs.shape else 1
    k = math.prod(lhs.shape[i] for i in lc) if lc else 1
    n = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                  if i not in rc and i not in rb) if rhs.shape else 1
    b = math.prod(lhs.shape[i] for i in lb) if lb else 1
    return 2 * b * m * n * k


def count_flops(jaxpr, mult: int = 1) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += mult * _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            out = _size(eqn.outvars[0].aval)
            rhs = eqn.invars[1].aval
            total += mult * 2 * out * _size(rhs)
        elif prim == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += count_flops(body, mult * eqn.params["length"])
        elif prim == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            total += count_flops(body, mult)  # trip count unknown: ×1
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max(count_flops(b.jaxpr, mult) for b in branches)
        elif prim == "shard_map":
            body = eqn.params["jaxpr"]
            body = body.jaxpr if hasattr(body, "jaxpr") else body
            mesh = eqn.params.get("mesh")
            n = mesh.size if mesh is not None else 1
            total += count_flops(body, mult * n)
        elif prim in ("pjit", "jit", "closed_call", "core_call", "remat_call",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat",
                      "remat2", "custom_vjp_call_fwd"):
            inner = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                total += count_flops(inner, mult)
        elif prim in _ELEMWISE:
            total += mult * sum(_size(v.aval) for v in eqn.outvars)
    return total


def step_flops(fn, *args) -> int:
    """Trace ``fn`` abstractly and count global FLOPs."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return count_flops(jaxpr.jaxpr)
