import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers AND compiles under the production sharding config, and
emit the compiled artifacts' memory/cost analyses for §Roofline.

No real buffers are ever allocated: parameters, optimizer state, batches and
caches are ShapeDtypeStructs with NamedShardings attached; the 512 host
devices exist only so ``jax.make_mesh`` can build the (2,16,16) mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single [--step auto|train|train_peft|prefill|
      decode|fl_round] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all  # full 40×2 matrix
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.configs import ASSIGNED, SHAPES, get_config
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_meshctx)
from repro.launch.steps import (make_fl_round_step, make_input_batch_shapes,
                                make_peft_step, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import Model
from repro.models import peft as peft_mod
from repro.sharding import (batch_specs, cache_specs, param_specs,
                            use_mesh, with_specs)

COLLECTIVE_RE = re.compile(
    r"(\w+\[[^\]]*\](?:\s*,\s*\w+\[[^\]]*\])*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)")
SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collective_bytes(hlo_text: str):
    """Per-device collective wire-byte estimate from post-SPMD HLO.

    Counts each collective op's RESULT shapes; all-reduce weighted 2× (ring
    reduce-scatter + all-gather decomposition).  This is the standard
    first-order model; exact DCN/ICI scheduling is hardware-dependent."""
    totals = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
              "all-to-all": 0, "collective-permute": 0}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", line)
        if not m:
            continue
        rest = m.group(1)
        cm = re.match(r"(\([^)]*\)|[\w\[\],{} ]+?)\s*"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)\b", rest)
        if not cm:
            continue
        shapes_str, op = cm.group(1), cm.group(2)
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(shapes_str):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        totals[op] += nbytes
    wire = (2 * totals["all-reduce"] + totals["all-gather"]
            + totals["reduce-scatter"] + totals["all-to-all"]
            + totals["collective-permute"])
    return totals, wire


def pick_impl(cfg, shape, opts=None):
    """Attention implementation per DESIGN.md §4: block-sparse (the paper's
    technique) is the sub-quadratic variant required for long_500k on
    attention archs; everything else uses the auto (dense/chunked) path.
    ``opts['sparse_impl']`` forces the paper's sparse attention everywhere
    (§Perf technique variants)."""
    if (opts or {}).get("sparse_impl") and not cfg.attention_free:
        return "sparse"
    if shape.name == "long_500k" and not cfg.attention_free:
        return "sparse"
    return "auto"


def build_specs(arch: str, shape_name: str, mesh_kind: str, step: str,
                dtype=jnp.bfloat16, opts=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    meshctx = make_meshctx(multi_pod=(mesh_kind == "multi"))
    impl = pick_impl(cfg, shape, opts)
    model = Model(cfg, meshctx=meshctx, dtype=dtype, impl=impl, remat=True,
                  opts=opts or {})
    return cfg, shape, meshctx, model, impl


def analytic_memory_bytes(cfg, shape, step, cache_bytes: int = 0) -> int:
    """First-order HBM traffic model (global, per step) — the napkin-math
    memory roofline term (cost_analysis undercounts scanned bodies):

    train:   4·P(bf16)  (fwd read + bwd read + grad w + opt read)
             + 16·N     (f32 moments read+write)
             + 6·L·T·d·2 (boundary activations: fwd w, bwd r, remat rw ×~3)
    prefill: P + 2·L·T·d·2 + cache write
    decode:  P_active + full cache read + small
    """
    p_bytes = cfg.param_count() * 2
    n = cfg.param_count()
    t = shape.global_batch * shape.seq_len
    layer_act = cfg.n_layers * cfg.d_model * 2
    if step in ("train", "train_peft", "fl_round"):
        return 4 * p_bytes + 16 * n + 6 * t * layer_act
    if step == "prefill":
        return p_bytes + 2 * t * layer_act + cache_bytes
    # decode: one token per sequence
    active = cfg.active_param_count() * 2
    return active + cache_bytes + shape.global_batch * layer_act


def sds_tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


def lower_one(arch: str, shape_name: str, mesh_kind: str, step: str = "auto",
              dtype=jnp.bfloat16, n_fl_clients: int = 8, opts=None,
              policy: str = "fsdp"):
    opts = dict(opts or {})
    if opts.get("sparse_kv"):
        opts["sparse_kv_seq"] = SHAPES[shape_name].seq_len
    cfg, shape, meshctx, model, impl = build_specs(arch, shape_name,
                                                   mesh_kind, step, dtype,
                                                   opts)
    if policy == "dp":
        # pure data parallelism: batch over ALL mesh axes (small models)
        import dataclasses as _dc
        assert not any(k.ff == "moe" for st_ in cfg.stages
                       for k in st_.pattern), "dp policy: non-MoE archs only"
        meshctx = _dc.replace(meshctx, batch_axes=meshctx.all_axes)
        model = Model(cfg, meshctx=meshctx, dtype=dtype, impl=impl,
                      remat=True, opts=opts, seq_shard_boundary=False)
    mesh = meshctx.mesh
    if step == "auto":
        step = {"train": "train", "prefill": "prefill",
                "decode": "decode"}[shape.kind]

    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shapes = jax.eval_shape(
        lambda k: model.init(k, max_seq=shape.seq_len + 8), key_s)
    # "zero1": params replicated over the data axes for compute (pure TP —
    # no per-layer weight gathers), optimizer moments FSDP-sharded; the
    # gather/scatter happens ONCE per step at the update.
    p_policy = "tp" if policy == "zero1" else policy
    o_policy = "fsdp" if policy == "zero1" else policy
    pspecs = param_specs(meshctx, params_shapes, cfg, policy=p_policy)
    params_in = with_specs(params_shapes, pspecs, mesh)

    batch_shapes = make_input_batch_shapes(cfg, shape, dtype)
    bspecs = batch_specs(meshctx, batch_shapes)
    batch_in = with_specs(batch_shapes, bspecs, mesh)

    if step == "train":
        step_fn, opt = make_train_step(model, impl=impl)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        ospecs = param_specs(meshctx, opt_shapes["mu"], cfg, policy=o_policy)
        opt_in = {"mu": with_specs(opt_shapes["mu"], ospecs, mesh),
                  "nu": with_specs(opt_shapes["nu"], ospecs, mesh),
                  "step": jax.ShapeDtypeStruct((), jnp.int32)}
        with use_mesh(mesh):
            lowered = jax.jit(step_fn).lower(params_in, opt_in, batch_in)
        lower_args = (step_fn, (params_in, opt_in, batch_in), 0)
    elif step == "train_peft":
        peft_cfg = peft_mod.PEFTConfig(lora_rank=16, adapter_dim=64)
        params_shapes2 = jax.eval_shape(
            lambda k: peft_mod.init_adapters(k, jax.eval_shape(
                lambda kk: model.init(kk, max_seq=shape.seq_len + 8), k),
                cfg, peft_cfg), key_s)
        # adapters/lora trainable; base frozen
        pspecs2 = param_specs(meshctx, params_shapes2, cfg)
        frozen_in = with_specs(params_shapes2, pspecs2, mesh)
        lora_shapes = jax.eval_shape(
            lambda k: peft_mod.init_lora(k, params_shapes2, peft_cfg), key_s)
        adapters = trees.select(params_shapes2, peft_mod.is_adapter_path)
        trainable_shapes = {"adapters": adapters, "lora": lora_shapes}
        tspecs = param_specs(meshctx, trainable_shapes, cfg)
        trainable_in = with_specs(trainable_shapes, tspecs, mesh)
        step_fn, opt = make_peft_step(model, peft_cfg, impl=impl)
        opt_shapes = jax.eval_shape(opt.init, trainable_shapes)
        opt_in = {"mu": with_specs(opt_shapes["mu"], tspecs, mesh),
                  "nu": with_specs(opt_shapes["nu"], tspecs, mesh),
                  "step": jax.ShapeDtypeStruct((), jnp.int32)}
        with use_mesh(mesh):
            lowered = jax.jit(step_fn).lower(trainable_in, frozen_in, opt_in,
                                             batch_in)
        lower_args = (step_fn, (trainable_in, frozen_in, opt_in, batch_in), 0)
    elif step == "prefill":
        step_fn = make_prefill_step(model, cache_len=shape.seq_len, impl=impl)
        with use_mesh(mesh):
            lowered = jax.jit(step_fn).lower(params_in, batch_in)
        cache_b = sds_tree_bytes(model.cache_spec(shape.global_batch,
                                                  shape.seq_len))
        lower_args = (step_fn, (params_in, batch_in), cache_b)
    elif step == "decode":
        step_fn = make_serve_step(model, impl=impl)
        cache_shapes = model.cache_spec(shape.global_batch, shape.seq_len)
        cspecs = cache_specs(meshctx, cache_shapes,
                             batch=shape.global_batch)
        cache_in = with_specs(cache_shapes, cspecs, mesh)
        tok_in = with_specs(
            jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            batch_specs(meshctx, jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32)), mesh)
        with use_mesh(mesh):
            lowered = jax.jit(step_fn).lower(params_in, cache_in, tok_in)
        lower_args = (step_fn, (params_in, cache_in, tok_in),
                      sds_tree_bytes(cache_shapes))
    elif step == "fl_round":
        # PFTT federated round: clients vmapped over the leading dim
        peft_cfg = peft_mod.PEFTConfig(lora_rank=16, adapter_dim=64)
        base_with_ad = jax.eval_shape(
            lambda k: peft_mod.init_adapters(k, jax.eval_shape(
                lambda kk: model.init(kk, max_seq=shape.seq_len + 8), k),
                cfg, peft_cfg), key_s)
        pspecs2 = param_specs(meshctx, base_with_ad, cfg)
        frozen_in = with_specs(base_with_ad, pspecs2, mesh)
        lora_shapes = jax.eval_shape(
            lambda k: peft_mod.init_lora(k, base_with_ad, peft_cfg), key_s)
        lora_c = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_fl_clients,) + s.shape, s.dtype),
            lora_shapes)
        adapters = trees.select(base_with_ad, peft_mod.is_adapter_path)
        trainable_shapes = {"adapters": adapters, "lora": lora_c}
        tspecs = param_specs(meshctx, trainable_shapes, cfg)
        # per-client leaves: client dim over the data axes
        tspecs = trees.map_with_path(
            lambda p, s: (batch_specs(meshctx, jax.ShapeDtypeStruct(
                trees.flatten(trainable_shapes)[p].shape, jnp.float32))
                if p.startswith("lora/") else s), tspecs)
        trainable_in = with_specs(trainable_shapes, tspecs, mesh)
        # per-client batch: fold client dim into batch dim shapes
        per_client = {k: jax.ShapeDtypeStruct(
            (n_fl_clients, max(1, v.shape[0] // n_fl_clients)) + v.shape[1:],
            v.dtype) for k, v in batch_shapes.items()}
        cbspecs = batch_specs(meshctx, per_client)
        batch_in = with_specs(per_client, cbspecs, mesh)
        step_fn, opt = make_fl_round_step(model, peft_cfg, n_fl_clients,
                                          impl=impl)
        opt_shapes = jax.eval_shape(opt.init, trainable_shapes)
        opt_in = {"mu": with_specs(opt_shapes["mu"], tspecs, mesh),
                  "nu": with_specs(opt_shapes["nu"], tspecs, mesh),
                  "step": jax.ShapeDtypeStruct((), jnp.int32)}
        with use_mesh(mesh):
            lowered = jax.jit(step_fn).lower(trainable_in, frozen_in, opt_in,
                                             batch_in)
        lower_args = (step_fn, (trainable_in, frozen_in, opt_in, batch_in), 0)
    else:
        raise ValueError(step)

    return cfg, shape, meshctx, lowered, step, impl, lower_args


def run_one(arch: str, shape_name: str, mesh_kind: str, step: str = "auto",
            out_dir: str = "experiments/dryrun", skip_hlo: bool = False,
            opts=None, policy: str = "fsdp", tag: str = ""):
    opts = opts or {}
    t0 = time.time()
    cfg, shape, meshctx, lowered, step, impl, lower_args = lower_one(
        arch, shape_name, mesh_kind, step, opts=opts, policy=policy)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # exact global FLOPs from the jaxpr (scan bodies × trip count,
    # remat recompute included)
    from repro.launch.jaxpr_cost import step_flops
    step_fn, abstract_args, cache_bytes = lower_args
    t0 = time.time()
    global_flops = step_flops(step_fn, *abstract_args)
    t_count = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    n_chips = meshctx.mesh.size
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    coll_detail, coll_wire = ({}, 0)
    if not skip_hlo:
        try:
            hlo = compiled.as_text()
            coll_detail, coll_wire = parse_collective_bytes(hlo)
        except Exception as e:  # pragma: no cover
            coll_detail = {"error": str(e)}

    eff_cache = cache_bytes
    if (step == "decode" and impl == "sparse"
            and opts.get("sparse_gather_decode") and cfg.sparse_attn
            and not cfg.attention_free):
        # gather-based sparse decode touches only the active blocks
        sp = cfg.sparse_attn
        nb = shape.seq_len // sp.block_size
        a = sp.sink_blocks + sp.local_blocks + max(1, nb // sp.stride)
        eff_cache = int(cache_bytes * min(1.0, a / nb))
    mem_global = analytic_memory_bytes(cfg, shape, step, eff_cache)
    compute_s = global_flops / n_chips / PEAK_FLOPS_BF16
    memory_s = mem_global / n_chips / HBM_BW
    collective_s = coll_wire / ICI_BW   # HLO is already per-device

    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "step": step,
        "impl": impl, "n_chips": n_chips, "opts": sorted(opts),
        "shard_policy": policy,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flop_count_s": round(t_count, 1),
        "global": {
            "jaxpr_flops": global_flops,
            "analytic_hbm_bytes": mem_global,
            "cache_bytes": cache_bytes,
        },
        "per_device": {
            "xla_flops_toplevel": xla_flops,
            "xla_bytes_toplevel": xla_bytes,
            "collective_wire_bytes": coll_wire,
            "collectives": coll_detail,
            "peak_memory_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
        },
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max([("compute", compute_s), ("memory", memory_s),
                             ("collective", collective_s)],
                            key=lambda kv: kv[1])[0],
        },
        "model_flops_total": model_flops,
        "useful_flops_ratio": (model_flops / global_flops
                               if global_flops else None),
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fname = f"{arch}_{shape_name}_{mesh_kind}_{step}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] {arch:18s} {shape_name:12s} {mesh_kind:6s} {step:10s} "
          f"OK lower={t_lower:.0f}s compile={t_compile:.0f}s "
          f"dom={result['roofline']['dominant']}")
    print(f"  memory_analysis: {mem}")
    print(f"  jaxpr_flops(global)={global_flops:.3e} "
          f"analytic_hbm(global)={mem_global:.3e} coll/dev={coll_wire:.3e}")
    print(f"  roofline/dev: compute={compute_s*1e3:.2f}ms "
          f"memory={memory_s*1e3:.2f}ms collective={collective_s*1e3:.2f}ms "
          f"useful_ratio={result['useful_flops_ratio'] and round(result['useful_flops_ratio'],3)}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--step", default="auto")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-hlo", action="store_true")
    ap.add_argument("--opts", default="",
                    help="comma list: causal_skip,sparse_gather_decode,"
                         "moe_a2a,mamba_sp,sparse_kv,sparse_impl")
    ap.add_argument("--shard-policy", default="fsdp",
                    choices=["fsdp", "fsdp_experts_only", "tp", "zero1", "dp"])
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix (perf variants)")
    args = ap.parse_args()
    opts = {k: True for k in args.opts.split(",") if k}

    if args.all:
        failures = []
        for arch in ASSIGNED:
            for shape in SHAPES:
                try:
                    run_one(arch, shape, args.mesh, out_dir=args.out,
                            skip_hlo=args.skip_hlo, opts=opts,
                            policy=args.shard_policy, tag=args.tag)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, str(e)[:200]))
        print(f"\n{len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    run_one(args.arch, args.shape, args.mesh, args.step, args.out,
            skip_hlo=args.skip_hlo, opts=opts, policy=args.shard_policy,
            tag=args.tag)


if __name__ == "__main__":
    main()
