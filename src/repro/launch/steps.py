"""Step builders: the jit-able programs the dry-run lowers and a real
launcher executes.

* ``train_step``   — full fine-tuning: loss → grads → AdamW update
* ``peft_step``    — paper-faithful PFTT training: only adapters (+LoRA)
                     receive gradients; the base is frozen/closed-over
* ``prefill_step`` — prompt forward + KV-cache construction
* ``serve_step``   — one decode token against the cache
* ``fl_round_step``— PFTT partial aggregation as ONE SPMD program: clients
                     are vmapped; shared adapters broadcast over the client
                     axis (their grads sum = FedAvg aggregation), per-client
                     LoRA keeps a leading client dim (never reduced) — the
                     paper's "aggregate adapters, keep LoRA local" stated as
                     autodiff structure + collectives.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import trees
from repro.models import Model
from repro.models import peft as peft_mod
from repro.optim import adamw


def make_input_batch_shapes(cfg, shape, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for one global batch of ``shape``."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.n_prefix_tokens:
        s_text = s - cfg.n_prefix_tokens
        batch = {"tokens": sds((b, s_text), jnp.int32),
                 "labels": sds((b, s_text), jnp.int32),
                 "mask": sds((b, s_text), dtype),
                 "patches": sds((b, cfg.n_prefix_tokens, cfg.prefix_dim), dtype)}
    elif cfg.is_encoder_decoder:
        batch = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32),
                 "mask": sds((b, s), dtype),
                 "frames": sds((b, cfg.encoder_seq, cfg.d_model), dtype)}
    else:
        batch = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32),
                 "mask": sds((b, s), dtype)}
    return batch


def make_train_step(model: Model, lr: float = 1e-4, impl: Optional[str] = None):
    opt = adamw(lr, weight_decay=0.01)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.lm_loss(p, batch, impl=impl)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return trees.tree_add(params, updates), opt_state, loss

    return train_step, opt


def make_peft_step(model: Model, peft_cfg: peft_mod.PEFTConfig,
                   lr: float = 1e-3, impl: Optional[str] = None,
                   factored: bool = True):
    """Paper-faithful PFTT local step: trainable = {adapters, lora}.

    ``factored`` (default) threads the LoRA factors through the forward
    unmerged (``peft.lora_proj``) — the dense delta is never formed;
    ``factored=False`` keeps the merged oracle."""
    opt = adamw(lr)
    scale = peft_mod.lora_scale(peft_cfg)

    def peft_step(trainable, frozen, opt_state, batch):
        def loss_fn(t):
            full = trees.merge(frozen, t["adapters"])
            if factored:
                return model.lm_loss(full, batch, impl=impl, lora=t["lora"],
                                     lora_scale=scale)
            eff = peft_mod.apply_lora(full, t["lora"], peft_cfg)
            return model.lm_loss(eff, batch, impl=impl)
        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        return trees.tree_add(trainable, updates), opt_state, loss

    return peft_step, opt


def make_prefill_step(model: Model, cache_len: int,
                      impl: Optional[str] = None, lora_scale: float = 1.0):
    """``prefill_step(params, batch, lora=None)``: the optional LoRA factor
    tree rides the factored side channel through prefill (never merged)."""
    def prefill_step(params, batch, lora=None):
        return model.prefill(params, batch["tokens"], cache_len,
                             frames=batch.get("frames"),
                             patches=batch.get("patches"), impl=impl,
                             lora=lora, lora_scale=lora_scale)
    return prefill_step


def make_serve_step(model: Model, impl: Optional[str] = None,
                    lora_scale: float = 1.0):
    """``serve_step(params, cache, tokens, lora=None)``: factored decode —
    per-client LoRA factors stay rank-r through the cached step."""
    def serve_step(params, cache, tokens, lora=None):
        return model.decode_step(params, cache, tokens, impl=impl,
                                 lora=lora, lora_scale=lora_scale)
    return serve_step


def make_fl_round_step(model: Model, peft_cfg: peft_mod.PEFTConfig,
                       n_clients: int, lr: float = 1e-3,
                       impl: Optional[str] = None, factored: bool = True):
    """One federated PFTT round as a single SPMD program.

    trainable = {"adapters": shared subtree (no client dim),
                 "lora": per-client subtree (leading n_clients dim)}
    batch leaves carry a leading client dim.  vmap broadcasts the adapters —
    so their cotangent SUMS over clients (= server aggregation), while LoRA
    cotangents stay per-client (= kept local).  Under the production mesh
    the client/batch dim is sharded over ("pod","data"): the adapter-grad
    reduction lowers to the cross-pod all-reduce that *is* the paper's
    communication step, and its payload is exactly the adapter subtree.

    The simulation engine now executes this same layout for real:
    ``core/cohort.py`` wraps its fused round in ``shard_map`` with the
    client axis over ("pod","data") and the stacked aggregation as explicit
    psums (``run_pftt``/``run_pfit`` ``mesh=``) — this builder remains the
    autodiff-structured statement the dry-run lowers/costs.

    ``factored`` (default) runs the LoRA path unmerged under the vmap, so
    the frozen base + adapters stay UNBATCHED (broadcast) and per-client
    state is just the rank-r factors — the memory/FLOP enabler for large
    cohorts; ``factored=False`` materializes the per-client merged weights
    (oracle)."""
    opt = adamw(lr)
    scale = peft_mod.lora_scale(peft_cfg)

    def fl_round_step(trainable, frozen, opt_state, batch):
        def loss_fn(t):
            full = trees.merge(frozen, t["adapters"])

            def client_loss(lora_c, batch_c):
                if factored:
                    return model.lm_loss(full, batch_c, impl=impl,
                                         lora=lora_c, lora_scale=scale)
                eff = peft_mod.apply_lora(full, lora_c, peft_cfg)
                return model.lm_loss(eff, batch_c, impl=impl)
            losses = jax.vmap(client_loss)(t["lora"], batch)
            return losses.mean()
        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        return trees.tree_add(trainable, updates), opt_state, loss

    return fl_round_step, opt


# spec-compliant alias: ShapeDtypeStruct stand-ins for every model input
def input_specs(cfg, shape, dtype=jnp.bfloat16):
    """Alias of make_input_batch_shapes (deliverable e naming)."""
    return make_input_batch_shapes(cfg, shape, dtype)
