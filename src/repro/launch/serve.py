"""Serving launcher: prefill + batched KV-cached decode, optionally with a
per-client LoRA (PFTT personalized serving).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --batch 4 --prompt-len 32 --gen 32
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs
from repro.models import Model
from repro.models import peft as peft_mod
from repro.sharding import MeshCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--lora-rank", type=int, default=0,
                    help="serve with a random personalized LoRA (PFTT mode)")
    ap.add_argument("--lora-merge", action="store_true",
                    help="legacy: bake the LoRA into the base weights "
                         "(default serves factored/unmerged via the fused "
                         "Pallas projection)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_only:
        raise SystemExit("encoder-only architectures have no decode path")
    serve_factored = bool(args.lora_rank) and not args.lora_merge
    model = Model(cfg, meshctx=MeshCtx.single_device(),
                  opts={"lora_backend": "pallas"} if serve_factored else None)
    key = jax.random.PRNGKey(0)
    params = model.init(key, max_seq=args.prompt_len + args.gen)
    lora, lscale = None, 1.0
    if args.lora_rank:
        pc = peft_mod.PEFTConfig(lora_rank=args.lora_rank)
        lora = peft_mod.init_lora(key, params, pc)
        lscale = peft_mod.lora_scale(pc)
        if args.lora_merge:
            params = peft_mod.merge_lora(params, lora, pc)
            lora = None
            print(f"serving with merged client LoRA (rank {args.lora_rank})")
        else:
            print(f"serving UNMERGED client LoRA (rank {args.lora_rank}, "
                  f"fused Pallas lowering): base stays shared")

    rng = np.random.RandomState(0)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jnp.asarray(rng.randn(args.batch, cfg.encoder_seq,
                                             cfg.d_model), jnp.float32)
    if cfg.n_prefix_tokens:
        kw["patches"] = jnp.asarray(rng.randn(args.batch, cfg.n_prefix_tokens,
                                              cfg.prefix_dim), jnp.float32)
    prompts = jnp.asarray(rng.randint(6, cfg.vocab_size,
                                      size=(args.batch, args.prompt_len)))

    decode = jax.jit(functools.partial(model.decode_step, lora=lora,
                                       lora_scale=lscale))
    t0 = time.time()
    logits, cache = model.prefill(params, prompts,
                                  cache_len=args.prompt_len + args.gen,
                                  lora=lora, lora_scale=lscale, **kw)
    print(f"prefill: {time.time()-t0:.2f}s "
          f"({args.batch}×{args.prompt_len} tokens)")
    t0 = time.time()
    out = []
    for _ in range(args.gen):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(nxt[:, 0]))
        logits, cache = decode(params, cache, nxt)
    dt = time.time() - t0
    print(f"decode: {args.gen} steps in {dt:.2f}s "
          f"→ {args.batch*args.gen/dt:.1f} tok/s")
    print("sample:", np.stack(out, 1)[0][:16])


if __name__ == "__main__":
    main()
