"""Uplink payload codecs: the wireless compression contract.

A codec is a *pure, jittable, single-client* encode→decode pair over a
trainable pytree plus a bit-accounting rule; the cohort engine
(``core/cohort.py``) vmaps ``roundtrip`` over the stacked client axis
INSIDE the compiled round step, so compression, the lossy decode the server
aggregates, and the per-client payload-bit count all ride the same fused
program (and compose with ``shard_map`` + ghost-padded cohorts unchanged).

Codec contract
--------------
* ``encode_leaf(key, delta, leaf_seed) -> enc`` / ``decode_leaf(enc, shape,
  leaf_seed) -> deltâ`` — leafwise, static output shapes.
* ``leaf_bits(enc, delta_shape, weight) -> f32 scalar`` — the uplink charge
  for that leaf.  Quantizers charge empirical-entropy bits (idealized
  adaptive arithmetic coder, ≤ qbits/element) + 16 bits per per-channel
  scale; sketches charge their static payload.
* Clients encode the **delta against the last server-known reference**
  (``ref=`` — the round-input value of the uploaded subtree, i.e. the
  previous broadcast global on every non-outage round).  Deltas are small
  and centred, which is what makes 4-bit stochastic rounding and top-k
  sparsification accurate.  After an all-outage round the simulation's
  per-client reference corresponds to the error-feedback bookkeeping a real
  deployment would keep; see ``docs/comms.md``.
* Leaves that are not worth coding (non-float, or smaller than
  ``MIN_CODED_SIZE`` — e.g. LoRA's ``(repeats, 1, 1)`` enable masks) are
  charged ``RAW_BITS``/element and pass through exactly.

``ChannelBudget`` is the bridge to the wireless layer: encoded payload
bits → ``RayleighChannel.uplink`` delay/outage plus transmit energy
(``tx_power_w · delay``), replacing the raw ``tree_bytes`` charge.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms import quantize, sketch
from repro.wireless.channel import ChannelReport, RayleighChannel

MIN_CODED_SIZE = 16    # leaves smaller than this ride raw (enable masks…)
SCALE_BITS = 16        # per-channel scales transmitted as bf16
RAW_BITS = 32          # uncoded float element


@dataclasses.dataclass(frozen=True)
class QuantCodec:
    """Stochastic-rounding int8/int4 per-channel quantization
    (``comms.quantize``)."""
    name: str
    qbits: int
    entropy_coded: bool = True

    def encode_leaf(self, key, delta, leaf_seed: int):
        return quantize.sr_quantize(key, delta, self.qbits)

    def decode_leaf(self, enc, shape, leaf_seed: int):
        return quantize.sr_dequantize(enc)

    def leaf_bits(self, enc, delta_shape, weight):
        if self.entropy_coded:
            data = quantize.symbol_entropy_bits(enc["q"], self.qbits, weight)
        else:
            data = (jnp.broadcast_to(weight, delta_shape)
                    .astype(jnp.float32).sum() * float(self.qbits))
        # per-channel scales ride only for channels that transmit at all
        # (a fully-masked leaf/channel sends nothing — weight-0 elements
        # are excluded from the bit charge, scales included)
        w = jnp.broadcast_to(weight, delta_shape)
        scale = enc["scale"]
        if scale.ndim == 0:
            nch = (w.max() > 0).astype(jnp.float32)
        else:
            ind = w
            for ax, s in enumerate(scale.shape):
                if s == 1:
                    ind = ind.max(axis=ax, keepdims=True)
            nch = (ind > 0).astype(jnp.float32).sum()
        return data + nch * SCALE_BITS


@dataclasses.dataclass(frozen=True)
class TopKCodec:
    """Top-k sparsification: k largest-|delta| entries as (f16 value, int32
    index) pairs (``comms.sketch``).  Static payload."""
    name: str = "sketch"
    frac: float = 0.1
    value_bits: int = 16
    index_bits: int = 32

    def encode_leaf(self, key, delta, leaf_seed: int):
        return sketch.topk_encode(delta, self.frac)

    def decode_leaf(self, enc, shape, leaf_seed: int):
        return sketch.topk_decode(enc, shape)

    def leaf_bits(self, enc, delta_shape, weight):
        # at most k (value, index) pairs, and never more than the number of
        # transmittable (weight>0) elements
        k = enc["idx"].shape[0]
        nnz = (jnp.broadcast_to(weight, delta_shape) > 0) \
            .astype(jnp.float32).sum()
        return jnp.minimum(float(k), nnz) * (self.value_bits
                                             + self.index_bits)


@dataclasses.dataclass(frozen=True)
class CountSketchCodec:
    """Count-sketch projection into ``rows`` hash rows (``comms.sketch``);
    hashes derive from the leaf's tree position, shared server-side for
    free.  Faithful only on heavy-hitter-dominated deltas."""
    name: str = "countsketch"
    ratio: float = 0.25
    rows: int = 3

    def encode_leaf(self, key, delta, leaf_seed: int):
        return sketch.count_sketch_encode(delta, leaf_seed=leaf_seed,
                                          rows=self.rows, ratio=self.ratio)

    def decode_leaf(self, enc, shape, leaf_seed: int):
        return sketch.count_sketch_decode(enc, shape, leaf_seed=leaf_seed)

    def leaf_bits(self, enc, delta_shape, weight):
        # a fully-masked leaf projects nothing: no sketch on the air
        any_tx = (jnp.broadcast_to(weight, delta_shape).max() > 0) \
            .astype(jnp.float32)
        return any_tx * enc["table"].size * 32


def get_codec(name: Optional[str], **kw):
    """Codec registry: none | int8 | int4 | sketch (top-k) | countsketch."""
    if name is None or name == "none":
        return None
    if name == "int8":
        return QuantCodec(name="int8", qbits=8, **kw)
    if name == "int4":
        return QuantCodec(name="int4", qbits=4, **kw)
    if name in ("sketch", "topk"):
        return TopKCodec(name="sketch", **kw)
    if name == "countsketch":
        return CountSketchCodec(**kw)
    raise ValueError(f"unknown uplink codec {name!r}; choose from "
                     "none,int8,int4,sketch,countsketch")

CODEC_NAMES = ("none", "int8", "int4", "sketch", "countsketch")


def _codable(x) -> bool:
    return (hasattr(x, "shape") and x.ndim >= 1
            and jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
            and x.size >= MIN_CODED_SIZE)


def roundtrip(codec, key, tree, *, ref=None, bit_weights=None):
    """Encode→decode one client's upload tree; returns ``(decoded_tree,
    payload_bits)`` with ``payload_bits`` a f32 scalar.

    ``ref`` (same structure, or None): the server-known reference — leaves
    are coded as ``leaf - ref`` and decoded as ``ref + deltâ``.
    ``bit_weights`` (same structure of broadcastable 0/1 masks, or None):
    elements with weight 0 are not transmitted — their delta is zeroed
    before encoding (decode preserves ``ref`` there) and they are excluded
    from the bit charge.  Vmap this over the stacked client axis to run the
    whole cohort's uplink inside one compiled round step."""
    if ref is None:
        ref = jax.tree_util.tree_map(lambda x: jnp.zeros((), x.dtype), tree)
    if bit_weights is None:
        bit_weights = jax.tree_util.tree_map(
            lambda x: jnp.ones((), jnp.float32), tree)
    seed = [0]
    bits_acc = []

    def one(x, rf, bw):
        i = seed[0]
        seed[0] += 1
        bwb = jnp.broadcast_to(bw, x.shape).astype(jnp.float32)
        if not _codable(x):
            bits_acc.append(bwb.sum() * RAW_BITS)
            # untransmitted (weight-0) lanes keep the server-known reference
            return jnp.where(bwb > 0, x, rf).astype(x.dtype)
        delta = (x - rf).astype(jnp.float32) * (bwb > 0)
        enc = codec.encode_leaf(jax.random.fold_in(key, i), delta, i)
        bits_acc.append(codec.leaf_bits(enc, x.shape, bwb))
        dec = codec.decode_leaf(enc, x.shape, i)
        return (rf + dec).astype(x.dtype)

    out = jax.tree_util.tree_map(one, tree, ref, bit_weights)
    total = jnp.asarray(0.0, jnp.float32)
    for b in bits_acc:
        total = total + jnp.asarray(b, jnp.float32)
    return out, total


def payload_bits_upper_bound(codec, tree) -> float:
    """Static (shape-only) worst-case payload bits — the flat charge before
    entropy coding; handy for capacity planning and sanity checks."""
    total = 0.0
    for x in jax.tree_util.tree_leaves(tree):
        if not hasattr(x, "size"):
            continue
        if not _codable(x):
            total += x.size * RAW_BITS
            continue
        if isinstance(codec, QuantCodec):
            total += x.size * codec.qbits
            total += quantize.channel_scale(
                jnp.zeros(x.shape), codec.qbits).size * SCALE_BITS
        elif isinstance(codec, TopKCodec):
            total += sketch.topk_k(x.size, codec.frac) * (
                codec.value_bits + codec.index_bits)
        elif isinstance(codec, CountSketchCodec):
            b = max(1, -(-int(round(x.size * codec.ratio)) // codec.rows))
            total += codec.rows * b * 32
        else:
            total += x.size * RAW_BITS
    return float(total)


def payload_checksum(tree) -> int:
    """Cheap host-side integrity checksum over an (encoded or decoded)
    payload tree: CRC-32 folded over every leaf's raw bytes in flat-key
    order.  The server verifies it before merging a delivery; a mismatch is
    a NACK into the retransmission path (``core/robust.StalenessTracker``
    with a ``DeadlineConfig`` — the seeded ``FaultPlan.corrupt_p`` mode
    models exactly this check failing in transit)."""
    import zlib

    from repro import trees as _trees

    crc = 0
    for p, x in sorted(_trees.flatten(tree).items()):
        if x is None:
            continue
        crc = zlib.crc32(p.encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(np.asarray(x)).tobytes(), crc)
    return crc & 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class ChannelBudget:
    """Bits → wireless budget bridge: encoded payload bits become per-client
    delay/outage through ``RayleighChannel.uplink`` and transmit energy
    ``tx_power_w · delay`` — this replaces the raw ``tree_bytes`` charge in
    the round loops."""
    channel: RayleighChannel
    tx_power_w: float = 0.5

    def report(self, payload_bits: float, gain: float) -> ChannelReport:
        rep = self.channel.uplink(float(payload_bits) / 8.0, gain=gain)
        energy = 0.0 if rep.outage else self.tx_power_w * rep.delay_s
        return dataclasses.replace(rep, energy_j=energy)

    def round_reports(self, bits_per_client: Sequence[float],
                      gains) -> list:
        return [self.report(b, g) for b, g in zip(bits_per_client, gains)]

    def tx_seconds(self, payload_bits: float, gain: float) -> float:
        """Airtime of ``payload_bits`` at the *realized* Rayleigh rate —
        no outage infinity: a failed attempt still occupied the channel
        (and burned energy) for this long.  Same ``max(rate, 1)`` floor as
        ``RayleighChannel.uplink``."""
        _, snr_lin = self.channel.snr(gain)
        rate = self.channel.bandwidth_hz * np.log2(1.0 + snr_lin)
        return float(payload_bits) / float(max(rate, 1.0))

    def attempt_report(self, payload_bits: float, gain: float, *,
                       tx_time_s: float, arrival_s: float,
                       delivered: bool) -> ChannelReport:
        """Per-attempt ledger entry for the continuous-time round: energy
        is charged for the attempt's airtime whether or not the server
        accepted it (outage, checksum NACK, deadline miss and quorum abort
        all still transmitted), bytes only count on delivery, and the delay
        is the scheduled arrival time within the round window."""
        snr_db, snr_lin = self.channel.snr(gain)
        rate = self.channel.bandwidth_hz * np.log2(1.0 + snr_lin)
        return ChannelReport(
            snr_db=float(snr_db), rate_bps=float(rate),
            delay_s=float(arrival_s) if delivered else float("inf"),
            outage=not delivered,
            bytes_sent=float(payload_bits) / 8.0 if delivered else 0,
            energy_j=self.tx_power_w * float(tx_time_s))
