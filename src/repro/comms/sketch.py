"""Sketch codecs: top-k sparsification and count-sketch.

Both are pure jittable leaf-level encode/decode pairs (the tree layer in
``comms.codec`` vmaps them over the stacked client axis).  Shapes are
static — ``k`` and the bucket count are computed from the leaf's static
size at trace time — so the encoded payload composes with ``shard_map``
and the ghost-padded cohorts of the sharded engine.

* **top-k** — transmit the k largest-|value| entries as (f16 value, int32
  index) pairs; decode scatters them back into zeros.  Deterministic (no
  PRNG).  This is the launcher-facing ``sketch`` codec.
* **count-sketch** — project the flattened leaf into ``rows`` hash rows of
  ``buckets`` signed buckets; decode reads ``sign·bucket[h(j)]`` and takes
  the median over rows.  The hash/sign streams are derived from a FIXED
  per-leaf key (``leaf_seed``), so server and every client share them with
  zero negotiation traffic.  Recovery is only faithful for heavy-hitter
  (top-k-dominated) signals — exactly the regime sparsified FL updates live
  in; see ``tests/test_comms.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_k(size: int, frac: float) -> int:
    return max(1, min(size, int(round(size * frac))))


def topk_encode(x, frac: float):
    """{'idx': int32 (k,), 'val': f16-rounded f32 (k,), 'shape': aux} for
    the k largest-magnitude entries of the flattened leaf."""
    flat = x.astype(jnp.float32).reshape(-1)
    k = topk_k(flat.shape[0], frac)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    val = flat[idx].astype(jnp.float16).astype(jnp.float32)
    return {"idx": idx.astype(jnp.int32), "val": val}


def topk_decode(enc, shape, dtype=jnp.float32):
    size = 1
    for s in shape:
        size *= s
    out = jnp.zeros((size,), jnp.float32).at[enc["idx"]].set(enc["val"])
    return out.reshape(shape).astype(dtype)


def _cs_hashes(leaf_seed: int, size: int, rows: int, buckets: int):
    """Static per-leaf hash/sign streams — identical on server and every
    client (derived from the leaf's position in the tree, not from data)."""
    hk = jax.random.PRNGKey(0x5EED ^ leaf_seed)
    h = jax.random.randint(hk, (rows, size), 0, buckets)
    sgn = jax.random.rademacher(jax.random.fold_in(hk, 1), (rows, size),
                                dtype=jnp.float32)
    return h, sgn


def count_sketch_encode(x, *, leaf_seed: int, rows: int, ratio: float):
    """Project the flattened leaf into (rows, buckets) signed buckets;
    ``buckets = ceil(size·ratio / rows)`` so the total sketch is ~ratio of
    the leaf."""
    flat = x.astype(jnp.float32).reshape(-1)
    size = flat.shape[0]
    buckets = max(1, -(-int(round(size * ratio)) // rows))
    h, sgn = _cs_hashes(leaf_seed, size, rows, buckets)
    table = jnp.zeros((rows, buckets), jnp.float32)
    for r in range(rows):
        table = table.at[r, h[r]].add(sgn[r] * flat)
    return {"table": table}


def count_sketch_decode(enc, shape, *, leaf_seed: int, dtype=jnp.float32):
    table = enc["table"]
    rows, buckets = table.shape
    size = 1
    for s in shape:
        size *= s
    h, sgn = _cs_hashes(leaf_seed, size, rows, buckets)
    est = jnp.stack([sgn[r] * table[r, h[r]] for r in range(rows)])
    return jnp.median(est, axis=0).reshape(shape).astype(dtype)
