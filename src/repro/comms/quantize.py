"""Stochastic-rounding per-channel quantization (the uplink's lossy core).

One leaf at a time, pure and jittable, so the tree layer (``comms.codec``)
can vmap the whole encode→decode roundtrip over the cohort engine's stacked
client axis and run it inside the compiled round step.

Scheme (per leaf):

* **channel axis** — the smaller of the last two dims (for a LoRA factor
  ``A (…, din, r)`` that is the rank axis; for ``B (…, r, dout)`` it is the
  rank axis again), so the per-channel scale vector stays tiny relative to
  the payload.  1-D leaves get a single per-tensor scale.
* **scale** — absmax of the channel divided by ``qmax = 2^(bits-1) - 1``,
  itself rounded through bfloat16 (the scale rides the payload at
  ``SCALE_BITS`` = 16 bits per channel — see ``comms.codec``).
* **stochastic rounding** — ``q = floor(x/scale + u)``, ``u ~ U[0, 1)``, so
  ``E[q·scale] = x`` exactly for every in-range element (the clip only
  guards float round-off at ±qmax).  Unbiasedness is what lets the server's
  weighted mean of decoded uploads converge like the uncompressed mean.

Bit accounting (``payload_bits``) charges the *empirical entropy* of the
quantized symbols — the idealized adaptive arithmetic/range coder every
practical uplink stack (QSGD's Elias coding, DEFLATE framing) approximates
— never more than ``bits`` per element, typically far less because absmax
scaling concentrates stochastic-rounded deltas near zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qmax_for(bits: int) -> int:
    """Largest symmetric integer level: 127 for int8, 7 for int4."""
    return 2 ** (bits - 1) - 1


def channel_scale(x, bits: int):
    """Per-channel absmax / qmax, rounded through bf16 (the transmitted
    precision).  Channel = the smaller of the last two dims; 1-D/0-D leaves
    get one per-tensor scale."""
    ax = jnp.abs(x.astype(jnp.float32))
    if x.ndim >= 2:
        axis = -2 if x.shape[-2] >= x.shape[-1] else -1
        s = jnp.max(ax, axis=axis, keepdims=True)
    else:
        s = jnp.max(ax)
    s = s / qmax_for(bits)
    # bias the bf16 rounding UP (1+2⁻⁷ > bf16's 2⁻⁸ ulp): a scale that
    # rounded down would push the channel's absmax element past qmax into
    # the clip, breaking stochastic-rounding unbiasedness at the boundary
    return (s * (1.0 + 2.0 ** -7)).astype(jnp.bfloat16).astype(jnp.float32)


def sr_quantize(key, x, bits: int):
    """Encode: {'q': int8 symbols in [-qmax, qmax], 'scale': bf16-rounded
    per-channel scales}.  All-zero channels produce scale 0 and q 0."""
    qm = qmax_for(bits)
    scale = channel_scale(x, bits)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    y = x.astype(jnp.float32) * inv
    u = jax.random.uniform(key, x.shape)
    q = jnp.clip(jnp.floor(y + u), -qm, qm).astype(jnp.int8)
    return {"q": q, "scale": scale}


def sr_dequantize(enc, dtype=jnp.float32):
    """Decode: q · scale."""
    return (enc["q"].astype(jnp.float32) * enc["scale"]).astype(dtype)


def symbol_entropy_bits(q, bits: int, weight=None):
    """Empirical-entropy payload charge for one leaf's symbols: n·H(q) bits,
    H over the ``2^bits``-ary histogram (idealized adaptive entropy coder —
    always ≤ n·bits).  ``weight`` (broadcastable 0/1, e.g. PFIT's sparsity
    mask) restricts the charge to transmitted elements."""
    nsym = 2 ** bits
    sym = (q.astype(jnp.int32) + nsym // 2).reshape(-1)
    if weight is None:
        w = jnp.ones(sym.shape, jnp.float32)
    else:
        w = jnp.broadcast_to(weight, q.shape).reshape(-1).astype(jnp.float32)
    hist = jnp.zeros((nsym,), jnp.float32).at[sym].add(w)
    n = hist.sum()
    p = hist / jnp.maximum(n, 1.0)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))
    return n * ent
