"""Wireless uplink payload codec subsystem (see ``docs/comms.md``).

``codec`` — tree-level encode/decode + bit accounting + ``ChannelBudget``;
``quantize`` — stochastic-rounding int8/int4 per-channel quantization;
``sketch`` — top-k and count-sketch codecs;
``factored_agg`` — SVD re-projection LoRA aggregation (no densification).
"""
from repro.comms.codec import (CODEC_NAMES, ChannelBudget,  # noqa: F401
                               CountSketchCodec, QuantCodec, TopKCodec,
                               get_codec, payload_bits_upper_bound,
                               payload_checksum, roundtrip)
from repro.comms.factored_agg import (dense_rank_r_oracle,  # noqa: F401
                                      factored_fedavg_tree, svd_reproject)
