"""Server-side LoRA factor aggregation WITHOUT densification.

The naive federated treatment of LoRA uploads averages the factors
elementwise, but ``avg_i(A_i·B_i) ≠ avg_i(A_i)·avg_i(B_i)`` — the mean of
the clients' low-rank *updates* has rank up to ``n·r`` and averaging A and B
separately is not even its best rank-r approximation.  The obvious fix
(materialize every ``A_i·B_i``, average, re-factor) costs an O(d²) dense
matrix on the server — exactly the memory the factored execution path
(PR 3) got rid of.

``svd_reproject`` computes the **best rank-r factorization of the weighted
mean update** while only ever touching (d × n·r) matrices:

    Δ = Σ_i ŵ_i A_i B_i = L·R,   L = [√ŵ_i A_i]_i  (din, m),  m = n·r
                                  R = [√ŵ_i B_i]_i  (m, dout)
    L = Q_l S_l   (thin QR)        R^T = Q_r S_r    (thin QR)
    U Σ V^T = svd(S_l S_r^T)       (m × m — tiny)
    A' = Q_l U_r √Σ_r,  B' = √Σ_r V_r^T Q_r^T       (rank r)

so ``A'·B'`` equals the rank-r-truncated SVD of Δ without Δ ever existing.
Cost is O(d·m²), memory O(d·m) — for a 4-client rank-8 cohort on a 4096-d
model that is 128k floats instead of 16M.

``factored_fedavg_tree`` applies this to every ``{'a','b'}`` sibling pair
in an uploaded tree (other leaves get the plain weighted mean) and is what
``core.aggregation.factored_fedavg_stacked`` dispatches to.  Under the
sharded engine the per-shard factor slices are ``all_gather``ed over the
client mesh axes first — factors are rank-r tiny, so gathering them is
cheap — and every shard computes the identical replicated re-projection.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import trees


def _normalized_weights(n: int, weights):
    if weights is None:
        return jnp.full((n,), 1.0 / n, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.maximum(w.sum(), 1e-12)


def _gather_clients(x, axis_names):
    return jax.lax.all_gather(x, axis_names, axis=0, tiled=True)


def svd_reproject(st_a, st_b, weights=None, rank: Optional[int] = None, *,
                  axis_names=None):
    """Stacked factors ``A (n, …, din, r)``, ``B (n, …, r, dout)`` and an
    (n,) weight vector → rank-``rank`` (default r) factors ``(A', B')`` of
    the weighted-mean update ``Σ ŵ_i A_i B_i``, never materializing any
    (din, dout) matrix.  Batched over leading dims (the layer-scan repeat
    axis).  ``axis_names``: inside ``shard_map``, all-gather the per-shard
    client slices over these mesh axes first (replicated result)."""
    if axis_names is not None:
        st_a = _gather_clients(st_a, axis_names)
        st_b = _gather_clients(st_b, axis_names)
        weights = _gather_clients(jnp.asarray(weights, jnp.float32),
                                  axis_names) if weights is not None else None
    n, r = st_a.shape[0], st_a.shape[-1]
    rank = r if rank is None else rank
    w = _normalized_weights(n, weights)
    sw = jnp.sqrt(w).reshape((n,) + (1,) * (st_a.ndim - 1))
    a = (st_a.astype(jnp.float32) * sw)
    b = (st_b.astype(jnp.float32) * sw)
    # (n, …, din, r) → (…, din, n·r)  /  (n, …, r, dout) → (…, n·r, dout)
    l = jnp.moveaxis(a, 0, -2)
    l = l.reshape(l.shape[:-3] + (l.shape[-3], n * r))
    rt = jnp.moveaxis(b, 0, -3)
    rt = rt.reshape(rt.shape[:-3] + (n * r, rt.shape[-1]))
    ql, sl = jnp.linalg.qr(l)                             # (…, din, m)
    qr_, sr_ = jnp.linalg.qr(jnp.swapaxes(rt, -1, -2))    # (…, dout, m)
    u, s, vt = jnp.linalg.svd(sl @ jnp.swapaxes(sr_, -1, -2),
                              full_matrices=False)        # m × m core
    root = jnp.sqrt(s[..., :rank])
    a_new = (ql @ u[..., :, :rank]) * root[..., None, :]
    b_new = (root[..., :, None] * vt[..., :rank, :]) @ \
        jnp.swapaxes(qr_, -1, -2)
    return a_new.astype(st_a.dtype), b_new.astype(st_b.dtype)


def dense_rank_r_oracle(st_a, st_b, weights=None, rank: Optional[int] = None):
    """Parity oracle: materialize the dense weighted-mean update, truncate
    its SVD to rank r, return the reconstruction.  O(d²) — tests/benchmarks
    only, NEVER the server path."""
    n, r = st_a.shape[0], st_a.shape[-1]
    rank = r if rank is None else rank
    w = _normalized_weights(n, weights)
    wr = w.reshape((n,) + (1,) * (st_a.ndim - 1))
    dense = jnp.einsum("n...dr,n...rf->...df",
                       st_a.astype(jnp.float32) * wr,
                       st_b.astype(jnp.float32))
    u, s, vt = jnp.linalg.svd(dense, full_matrices=False)
    return (u[..., :, :rank] * s[..., None, :rank]) @ vt[..., :rank, :]


def _factor_pairs(flat):
    """{'…/a': leaf} paths with a '…/b' sibling → [(base, path_a, path_b)]."""
    pairs = []
    for p in flat:
        if p.endswith("/a") and (p[:-2] + "/b") in flat:
            pairs.append((p[:-2], p, p[:-2] + "/b"))
    return pairs


def factored_fedavg_tree(stacked_tree, weights=None, *, axis_names=None,
                         rank: Optional[int] = None):
    """Weighted-mean aggregation of a stacked upload tree where every
    ``{'a','b'}`` factor pair aggregates as the rank-r SVD re-projection of
    ``Σ ŵ_i A_i·B_i`` (``svd_reproject``) and every other leaf gets the
    plain stacked weighted mean.  Drop-in replacement for
    ``fedavg_stacked`` on factor-bearing trees."""
    from repro.core.aggregation import fedavg_stacked
    avg = fedavg_stacked(stacked_tree, weights, axis_names=axis_names)
    flat = trees.flatten(stacked_tree)
    repl = {}
    for _, pa, pb in _factor_pairs(flat):
        a_new, b_new = svd_reproject(flat[pa], flat[pb], weights, rank,
                                     axis_names=axis_names)
        repl[pa], repl[pb] = a_new, b_new
    if not repl:
        return avg
    return trees.map_with_path(lambda p, v: repl.get(p, v), avg)
