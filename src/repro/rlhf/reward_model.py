"""Reward models (paper §IV-C: double reward model for helpfulness/safety).

A reward model is a small causal transformer with a scalar head over
masked-mean pooled hidden states.  Training uses Bradley–Terry pairwise
ranking loss on pairs ordered by the corpus's ground-truth latent scores —
the synthetic stand-in for the paper's human rankers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LK, ModelConfig, Stage
from repro.data.synthetic import VOCAB
from repro.models import Model
from repro.optim import adamw
from repro.sharding import MeshCtx
from repro import trees


def reward_model_config(d_model: int = 128, n_layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name="reward-model",
        family="dense",
        d_model=d_model,
        n_heads=4,
        n_kv_heads=4,
        head_dim=d_model // 4,
        d_ff=4 * d_model,
        vocab_size=VOCAB,
        stages=(Stage((LK("attn", "mlp"),), repeats=n_layers),),
        act="gelu",
        norm="ln",
        pos="learned",
        max_position=1024,
        tie_embeddings=True,
    )


@dataclasses.dataclass
class RewardModel:
    model: Model
    params: dict

    @classmethod
    def create(cls, key, d_model: int = 128, n_layers: int = 2,
               meshctx=None) -> "RewardModel":
        cfg = reward_model_config(d_model, n_layers)
        model = Model(cfg, meshctx=meshctx or MeshCtx.single_device())
        params = model.init(key)
        k2 = jax.random.fold_in(key, 1)
        params["reward_head"] = (
            jax.random.normal(k2, (cfg.d_model, 1)) * cfg.d_model ** -0.5)
        return cls(model=model, params=params)

    def score(self, params, tokens, mask):
        """tokens (B,S), mask (B,S) → scalar scores (B,)."""
        hidden, _ = self.model.forward(params, tokens)
        m = mask[..., None].astype(hidden.dtype)
        pooled = (hidden * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
        return (pooled @ params["reward_head"])[:, 0].astype(jnp.float32)


def train_reward_model(key, rm: RewardModel, samples: dict, target: str,
                       *, steps: int = 300, batch: int = 32,
                       lr: float = 3e-4, log_every: int = 0):
    """Bradley–Terry training: rank pairs by ground-truth ``samples[target]``
    (``help`` or ``safe``).  Returns trained params + final pair accuracy."""
    tokens = samples["tokens"]
    mask = samples["mask"] if "mask" in samples else np.ones_like(tokens, np.float32)
    gt = samples[target]
    n = len(tokens)
    opt = adamw(lr)
    opt_state = opt.init(rm.params)
    params = rm.params
    rng = np.random.RandomState(0)

    @jax.jit
    def step_fn(params, opt_state, tw, mw, tl, ml):
        def loss_fn(p):
            sw = rm.score(p, tw, mw)
            sl = rm.score(p, tl, ml)
            return -jax.nn.log_sigmoid(sw - sl).mean()
        loss, g = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(g, opt_state, params)
        return trees.tree_add(params, updates), opt_state, loss

    last = 0.0
    for s in range(steps):
        i = rng.randint(0, n, size=batch)
        j = rng.randint(0, n, size=batch)
        swap = gt[i] < gt[j]
        wi = np.where(swap, j, i)
        li = np.where(swap, i, j)
        params, opt_state, loss = step_fn(
            params, opt_state, tokens[wi], mask[wi], tokens[li], mask[li])
        last = float(loss)
        if log_every and s % log_every == 0:
            print(f"  rm[{target}] step {s} bt-loss {last:.4f}")

    # pair accuracy on fresh pairs
    i = rng.randint(0, n, size=256)
    j = rng.randint(0, n, size=256)
    si = np.asarray(rm.score(params, tokens[i], mask[i]))
    sj = np.asarray(rm.score(params, tokens[j], mask[j]))
    valid = gt[i] != gt[j]
    acc = float((((si > sj) == (gt[i] > gt[j])) & valid).sum()
                / max(valid.sum(), 1))
    return params, {"bt_loss": last, "pair_acc": acc}
