from repro.rlhf.reward_model import RewardModel, train_reward_model  # noqa: F401
from repro.rlhf.rollout import generate  # noqa: F401
from repro.rlhf.ppo import PPOConfig, ppo_round  # noqa: F401
