"""PPO for LM fine-tuning (paper §IV-C Step 3: update the unfrozen part of
the local LLM with PPO against the personalized reward function).

Standard clipped-PPO with GAE, a learned value head over hidden states, and
a per-token KL penalty to the round's reference (global) policy.  The
terminal reward is the client's personalized quality reward (double reward
model combination) plus the negative L2 regularization toward the global
model — exactly the paper's reward decomposition.

``PPOTrainer`` builds its jitted phases once (rollout-stats prep + clipped
update) so per-round calls don't retrace.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import trees


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    gen_len: int = 24
    clip: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.001
    kl_coef: float = 0.05
    gamma: float = 1.0
    lam: float = 0.95
    temperature: float = 1.0
    ppo_epochs: int = 2


def seq_logprobs_values(model, params, tokens):
    """LM shift: hidden at position i scores token i+1.
    Returns logp (B, S-1), values (B, S-1), entropy (B, S-1)."""
    hidden, _ = model.forward(params, tokens[:, :-1])
    logits = model.logits(params, hidden)                  # (B, S-1, V)
    logall = jax.nn.log_softmax(logits, axis=-1)
    logp = jnp.take_along_axis(logall, tokens[:, 1:, None], axis=-1)[..., 0]
    ent = -(jnp.exp(logall) * logall).sum(-1)
    # value head reads a DETACHED trunk: the critic regression must not
    # distort the policy's representation (single-trunk PPO pathology)
    values = (jax.lax.stop_gradient(hidden).astype(jnp.float32)
              @ params["value_head"].astype(jnp.float32))[..., 0]
    return logp, values, ent


def gae(rewards, values, mask, gamma: float, lam: float):
    """rewards/values/mask: (B, T) → (advantages, returns)."""
    def scan_fn(carry, xs):
        r, v, v_next, m = xs
        delta = r + gamma * v_next * m - v
        adv = delta + gamma * lam * m * carry
        return adv, adv

    v_next = jnp.concatenate([values[:, 1:], jnp.zeros_like(values[:, :1])], 1)
    xs = (rewards.T, values.T, v_next.T, mask.T)
    xs = jax.tree_util.tree_map(lambda x: x[::-1], xs)
    _, adv_rev = jax.lax.scan(scan_fn, jnp.zeros(rewards.shape[0]), xs)
    adv = adv_rev[::-1].T
    return adv, adv + values


def make_ppo_fns(model, opt, cfg: PPOConfig, prompt_len: int):
    """Unjitted (prep, step) pair — PPOTrainer jits them for the per-client
    loop; the cohort engine vmaps them over a stacked client axis instead."""

    def prep(params, ref_params, tokens, terminal_reward):
        resp_mask = (jnp.arange(tokens.shape[1] - 1)[None]
                     >= prompt_len - 1).astype(jnp.float32)
        resp_mask = jnp.broadcast_to(resp_mask, tokens[:, 1:].shape)
        old_logp, old_values, _ = seq_logprobs_values(model, params, tokens)
        ref_logp, _, _ = seq_logprobs_values(model, ref_params, tokens)
        kl = old_logp - ref_logp
        rewards = -cfg.kl_coef * kl * resp_mask
        rewards = rewards.at[:, -1].add(terminal_reward)
        adv, ret = gae(rewards, old_values, resp_mask, cfg.gamma, cfg.lam)
        adv = (adv - adv.mean()) / jnp.maximum(adv.std(), 1e-6)
        mean_kl = (kl * resp_mask).sum() / resp_mask.sum()
        return old_logp, adv, ret, resp_mask, mean_kl

    def step(params, opt_state, tokens, old_logp, adv, ret, resp_mask,
             grad_mask):
        def loss_fn(p):
            logp, values, ent = seq_logprobs_values(model, p, tokens)
            ratio = jnp.exp(logp - old_logp)
            unclipped = ratio * adv
            clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv
            denom = resp_mask.sum()
            pg = -(jnp.minimum(unclipped, clipped) * resp_mask).sum() / denom
            vf = (jnp.square(values - ret) * resp_mask).sum() / denom
            en = (ent * resp_mask).sum() / denom
            return pg + cfg.vf_coef * vf - cfg.ent_coef * en, (pg, vf, en)

        (loss, auxes), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if grad_mask is not None:
            grads = jax.tree_util.tree_map(
                lambda g, m: g * jnp.asarray(m, g.dtype), grads, grad_mask)
        updates, opt_state = opt.update(grads, opt_state, params)
        return trees.tree_add(params, updates), opt_state, loss, auxes

    return prep, step


class PPOTrainer:
    def __init__(self, model, opt, cfg: PPOConfig, prompt_len: int):
        self.model = model
        self.opt = opt
        self.cfg = cfg
        self.prompt_len = prompt_len
        prep, step = make_ppo_fns(model, opt, cfg, prompt_len)
        self._prep = jax.jit(prep)
        self._step = jax.jit(step)

    def round(self, params, ref_params, opt_state, tokens, terminal_reward,
              grad_mask=None):
        """One PPO pass (cfg.ppo_epochs clipped updates) over a rollout batch."""
        old_logp, adv, ret, resp_mask, mean_kl = self._prep(
            params, ref_params, tokens, terminal_reward)
        stats = {}
        for _ in range(self.cfg.ppo_epochs):
            params, opt_state, loss, (pg, vf, en) = self._step(
                params, opt_state, tokens, old_logp, adv, ret, resp_mask,
                grad_mask)
        stats = {"loss": float(loss), "pg": float(pg), "vf": float(vf),
                 "entropy": float(en), "kl": float(mean_kl)}
        return params, opt_state, stats


def ppo_round(model, params, ref_params, opt, opt_state, rollout_tokens,
              prompt_len: int, terminal_reward, cfg: PPOConfig,
              grad_mask=None):
    """One-shot convenience wrapper (tests).  Builds a trainer per call —
    use PPOTrainer directly in loops."""
    tr = PPOTrainer(model, opt, cfg, prompt_len)
    return tr.round(params, ref_params, opt_state, rollout_tokens,
                    terminal_reward, grad_mask)
