"""Autoregressive rollout for PPO — reuses the serving path (prefill +
KV-cached decode scan), the same machinery the inference launcher uses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def generate(model, params, prompts, gen_len: int, key, *,
             temperature: float = 1.0, lora=None, lora_scale: float = 1.0):
    """prompts: (B, P) int32 → tokens (B, P+gen_len).

    Fixed-length generation (EOS handled by the reward masks downstream);
    scan over decode steps with a KV cache.  ``lora`` serves a personalized
    client unmerged: prefill and every decode step run the factored
    projections (``peft.lora_proj``), the base stays shared."""
    b, p = prompts.shape
    logits, cache = model.prefill(params, prompts, cache_len=p + gen_len,
                                  lora=lora, lora_scale=lora_scale)

    def step(carry, k):
        logits, cache = carry
        tok = jax.random.categorical(k, logits / temperature, axis=-1)
        tok = tok[:, None].astype(jnp.int32)
        new_logits, cache = model.decode_step(params, cache, tok, lora=lora,
                                              lora_scale=lora_scale)
        return (new_logits, cache), tok[:, 0]

    keys = jax.random.split(key, gen_len)
    _, toks = jax.lax.scan(step, (logits, cache), keys)
    return jnp.concatenate([prompts, toks.T], axis=1)
