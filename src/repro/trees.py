"""Pytree path utilities: flatten to '/'-joined path dicts, select subtrees
by predicate, merge, stack along a client axis — the substrate for PEFT
splits, federated partial aggregation, and the vmapped cohort engine."""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def flatten(tree) -> Dict[str, object]:
    """→ {'stages/0/layers/1/mixer/wq': leaf, ...} (treedef discarded)."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(_key_str(k) for k in path): v for path, v in leaves}


def map_with_path(fn: Callable[[str, object], object], tree):
    """tree_map with the '/'-joined path passed to fn."""
    return jax.tree_util.tree_map_with_path(
        lambda path, v: fn("/".join(_key_str(k) for k in path), v), tree)


def select(tree, pred: Callable[[str], bool]):
    """Keep leaves whose path satisfies pred; others become None (structure
    preserved — mergeable with ``merge``)."""
    return map_with_path(lambda p, v: v if pred(p) else None, tree)


def merge(base, overlay):
    """Take overlay leaf where not None, else base leaf.  Same structure."""
    return jax.tree_util.tree_map(
        lambda b, o: b if o is None else o, base, overlay,
        is_leaf=lambda x: x is None)


def mask_like(tree, pred: Callable[[str], bool]):
    """1.0/0.0 float mask tree by path predicate."""
    return map_with_path(lambda p, v: float(pred(p)), tree)


def stack(client_trees: Sequence):
    """Stack same-structure trees along a NEW leading client axis per leaf:
    n trees of leaf shape S → one tree of leaf shape (n, *S).  The stacked
    form is what the cohort engine vmaps over."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *client_trees)


def unstack(stacked, n: Optional[int] = None) -> List:
    """Inverse of ``stack``: split the leading client axis back into a list
    of per-client trees (device-side slices, no host transfer)."""
    if n is None:
        n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    return [jax.tree_util.tree_map(lambda l: l[i], stacked) for i in range(n)]


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


def byte_size(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))


def tree_add(a, b, scale_b: float = 1.0):
    return jax.tree_util.tree_map(lambda x, y: x + scale_b * y, a, b)


def tree_scale(a, s: float):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jax.numpy.zeros_like, a)


def tree_l2(a, b) -> object:
    """Global squared L2 distance between two trees."""
    import jax.numpy as jnp
    d = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(jnp.square(x.astype(jnp.float32)
                                        - y.astype(jnp.float32))), a, b)
    return jax.tree_util.tree_reduce(lambda x, y: x + y, d)
