"""Nested host span tracer → Chrome trace-event JSON (Perfetto-loadable).

One ``SpanTracer`` instance per run.  ``with tracer.span("gather"):``
times a host phase; spans nest naturally (a ``span`` opened inside
another span renders as its child in Perfetto, because complete-"X"
events on one track nest by time containment).  The tracer ALWAYS times
— even disabled it accumulates per-phase durations, which is how
``PopulationRunner`` keeps its ``host_s``/``round_s`` accounting and how
the telemetry round events get their ``wall.phases`` breakdown — but it
only *records* Chrome trace events when ``enabled=True``, so the
disabled tracer costs two ``perf_counter`` calls and a dict add per
span.

Span-name convention (used by every runner; see docs/observability.md):

    round        whole-round wrapper (population runner)
    sample       cohort sampling (population) / host batch draw (cohort)
    plan         StalenessTracker round plan (population)
    gather       store gather + global overlay + device_put / batch stack
    encode       codec PRNG key build (host side of the compressed uplink)
    device-step  the ONE fused compiled round dispatch (+block_until_ready)
    scatter      device→store writeback + global snapshot
    ledger       channel reports + CommLedger append
    eval         fused cohort eval dispatch
    checkpoint   round-level checkpoint save

``chrome_trace()``/``write()`` emit the standard
``{"traceEvents": [...]}`` JSON object format: load the file in
https://ui.perfetto.dev (or chrome://tracing) directly.

``jax_profile_start``/``jax_profile_stop`` bracket the run with
``jax.profiler`` for device-side traces (TensorBoard/Perfetto); they are
best-effort — a backend without profiler support degrades to a no-op
instead of failing the run.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager
from typing import Dict, List, Optional


class Span:
    """Handle yielded by ``SpanTracer.span``: ``dur`` (seconds) is set
    when the ``with`` block exits."""

    __slots__ = ("name", "start", "dur")

    def __init__(self, name: str, start: float):
        self.name = name
        self.start = start
        self.dur = 0.0


class SpanTracer:
    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._t0 = time.perf_counter()
        self._events: List[Dict] = []
        self._depth = 0
        self._round_acc: Dict[str, float] = {}   # since last pop_round()
        self._total_acc: Dict[str, float] = {}   # whole run

    @contextmanager
    def span(self, name: str, **args):
        start = time.perf_counter()
        sp = Span(name, start)
        self._depth += 1
        try:
            yield sp
        finally:
            end = time.perf_counter()
            self._depth -= 1
            sp.dur = end - start
            self._round_acc[name] = self._round_acc.get(name, 0.0) + sp.dur
            self._total_acc[name] = self._total_acc.get(name, 0.0) + sp.dur
            if self.enabled:
                ev = {"name": name, "ph": "X", "pid": os.getpid(), "tid": 1,
                      "ts": (start - self._t0) * 1e6, "dur": sp.dur * 1e6}
                if args:
                    ev["args"] = args
                self._events.append(ev)

    # ---- per-round / whole-run accounting ---------------------------------

    def pop_round(self) -> Dict[str, float]:
        """Per-span-name seconds accumulated since the last call (the
        telemetry round event's ``wall.phases``) — and reset."""
        out = {k: float(v) for k, v in self._round_acc.items()}
        self._round_acc = {}
        return out

    def totals(self) -> Dict[str, float]:
        """Whole-run per-span-name seconds (never reset)."""
        return {k: float(v) for k, v in self._total_acc.items()}

    # ---- Chrome trace-event JSON ------------------------------------------

    def chrome_trace(self) -> Dict:
        return {"traceEvents": list(self._events), "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        """Atomic write (tmp + replace) so a kill mid-dump never leaves a
        truncated trace next to a valid event stream."""
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)


# ---------------------------------------------------------------------------
# optional jax.profiler bracket (device-side traces)
# ---------------------------------------------------------------------------


def jax_profile_start(out_dir: str) -> bool:
    """Best-effort ``jax.profiler.start_trace``; False when the backend
    has no profiler (the run continues without device traces)."""
    try:
        import jax
        os.makedirs(out_dir, exist_ok=True)
        jax.profiler.start_trace(out_dir)
        return True
    except Exception:
        return False


def jax_profile_stop() -> None:
    try:
        import jax
        jax.profiler.stop_trace()
    except Exception:
        pass
