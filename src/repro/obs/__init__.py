"""Run observability: structured JSONL round telemetry, nested host span
tracing (Chrome trace-event / Perfetto), and on-device training-health
scalars that ride the fused round outputs.  See docs/observability.md.
"""
from repro.obs.metrics import (SCHEMA_VERSION, RunTelemetry, TelemetryConfig,
                               canonical_stream, read_events, validate_events)
from repro.obs.trace import (SpanTracer, jax_profile_start, jax_profile_stop)
from repro.obs.health import HEALTH_KEYS, cohort_health, host_health

__all__ = [
    "SCHEMA_VERSION", "RunTelemetry", "TelemetryConfig",
    "canonical_stream", "read_events", "validate_events",
    "SpanTracer", "jax_profile_start", "jax_profile_stop",
    "HEALTH_KEYS", "cohort_health", "host_health",
]
