"""Schema-versioned JSONL run telemetry.

One ``RunTelemetry`` per run writes ``events.jsonl`` under ``out_dir``:
one JSON object per line, append-only, flushed per event so a killed
run leaves a valid prefix.  Event types (``"event"`` key):

    run         first line of a fresh stream: schema version + run meta
    round       one per federated round — THE joined record: eval metric,
                CommLedger bits/delay/energy, StalenessTracker counters,
                sampler cohort ids, on-device health scalars, and the
                per-phase host timings under ``wall``
    checkpoint  a round-level checkpoint was persisted (after its round
                event — ordering is the exactly-once resume contract)
    resume      a run re-attached to this stream at ``start_round``
    compile     a compiled-dispatch warmup was observed (round 0 wall
                time includes compilation; this marks it)

Resume contract (mirrors the PR 6/9 checkpoint semantics): everything
volatile across identical replays — wall-clock timings, host phase
breakdowns — lives under the single reserved ``"wall"`` key of each
event.  ``canonical_stream`` strips ``wall`` and the lifecycle events
(run/checkpoint/resume/compile) and renders each round event as
canonical JSON; a killed-and-resumed run must reproduce the
uninterrupted run's canonical stream byte-for-byte.  ``resume()``
enforces the no-duplicates half: it drops any recorded events with
``round >= start_round`` (present when the kill landed between a round
event and its checkpoint) before appending continues.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1

_LIFECYCLE = ("run", "checkpoint", "resume", "compile")
_EVENT_TYPES = _LIFECYCLE + ("round",)


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """What to record.  ``out_dir=None`` (via config default) disables
    everything; ``health`` additionally rides device-side training-health
    scalars on the fused round outputs (still one dispatch/round)."""

    out_dir: str
    trace: bool = False         # Chrome trace-event JSON (trace.json)
    jax_profile: bool = False   # device traces via jax.profiler
    health: bool = True         # on-device health scalars in round events


def _sanitize(obj):
    """NaN/Inf → None recursively: the stream must be strict JSON (an
    all-outage round has NaN delay_s in the ledger record)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    return obj


def _dumps(ev: Dict) -> str:
    return json.dumps(_sanitize(ev), sort_keys=True, separators=(",", ":"))


class RunTelemetry:
    """JSONL event recorder.  ``out_dir=None`` → fully disabled (every
    method is a cheap no-op), so runners thread one object through
    unconditionally."""

    def __init__(self, out_dir: Optional[str] = None, tracer=None):
        self.out_dir = out_dir
        self.tracer = tracer
        self.enabled = out_dir is not None
        self.path = os.path.join(out_dir, "events.jsonl") if out_dir else None
        if self.enabled:
            os.makedirs(out_dir, exist_ok=True)

    # ---- low-level append --------------------------------------------------

    def _emit(self, ev: Dict) -> None:
        if not self.enabled:
            return
        line = _dumps(ev)
        # open-append-close per event: one line is one atomic-enough unit;
        # a kill mid-run leaves a valid JSONL prefix, never a torn stream.
        with open(self.path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    # ---- lifecycle ---------------------------------------------------------

    def start(self, run_meta: Optional[Dict] = None) -> None:
        """Begin a FRESH stream (truncates any stale file at this path)."""
        if not self.enabled:
            return
        with open(self.path, "w"):
            pass
        self._emit({"event": "run", "schema": SCHEMA_VERSION,
                    "meta": run_meta or {}})

    def resume(self, start_round: int, run_meta: Optional[Dict] = None) -> None:
        """Re-attach to an existing stream: keep the run event and all
        rounds < start_round, drop rounds >= start_round (recorded but
        not checkpointed before the kill), then mark the resume."""
        if not self.enabled:
            return
        kept: List[Dict] = []
        if os.path.exists(self.path):
            for ev in read_events(self.path):
                if ev.get("event") == "round" and ev.get("round", -1) >= start_round:
                    continue
                kept.append(ev)
        if not any(ev.get("event") == "run" for ev in kept):
            kept.insert(0, {"event": "run", "schema": SCHEMA_VERSION,
                            "meta": run_meta or {}})
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            for ev in kept:
                f.write(_dumps(ev) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self._emit({"event": "resume", "round": int(start_round),
                    "wall": {"meta": run_meta or {}}})

    def checkpoint(self, rnd: int) -> None:
        self._emit({"event": "checkpoint", "round": int(rnd)})

    def compile_event(self, rnd: int, seconds: float) -> None:
        self._emit({"event": "compile", "round": int(rnd),
                    "wall": {"seconds": float(seconds)}})

    # ---- the joined per-round record ---------------------------------------

    def round_event(self, rnd: int, data: Dict[str, Any],
                    wall: Optional[Dict[str, Any]] = None) -> None:
        """``data`` holds the replay-stable joined record (metric, comm,
        staleness, cohort, health); ``wall`` holds everything volatile."""
        if not self.enabled:
            return
        ev = dict(data)
        ev["event"] = "round"
        ev["round"] = int(rnd)
        ev["wall"] = wall or {}
        self._emit(ev)

    def close(self) -> None:
        """Dump the Chrome trace next to the event stream (if tracing)."""
        if self.enabled and self.tracer is not None and self.tracer.enabled:
            self.tracer.write(os.path.join(self.out_dir, "trace.json"))


# ---------------------------------------------------------------------------
# stream readers / validators (launch/report.py + tests)
# ---------------------------------------------------------------------------


def read_events(path: str) -> List[Dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def canonical_stream(events: List[Dict]) -> List[str]:
    """Round events only, ``wall`` stripped, canonical JSON — the byte
    sequence the kill/resume contract compares."""
    out = []
    for ev in events:
        if ev.get("event") != "round":
            continue
        ev = {k: v for k, v in ev.items() if k != "wall"}
        out.append(_dumps(ev))
    return out


def validate_events(events: List[Dict]) -> List[str]:
    """Schema check → list of human-readable problems (empty = valid)."""
    errs: List[str] = []
    if not events:
        return ["empty event stream"]
    head = events[0]
    if head.get("event") != "run":
        errs.append("first event is %r, expected 'run'" % head.get("event"))
    elif head.get("schema") != SCHEMA_VERSION:
        errs.append("schema version %r, expected %d"
                    % (head.get("schema"), SCHEMA_VERSION))
    seen_rounds: List[int] = []
    for i, ev in enumerate(events):
        kind = ev.get("event")
        if kind not in _EVENT_TYPES:
            errs.append("event %d: unknown type %r" % (i, kind))
            continue
        if kind == "round":
            if not isinstance(ev.get("round"), int):
                errs.append("event %d: round id missing" % i)
                continue
            r = ev["round"]
            if r in seen_rounds:
                errs.append("duplicate round %d" % r)
            if seen_rounds and r <= seen_rounds[-1]:
                errs.append("round %d out of order after %d"
                            % (r, seen_rounds[-1]))
            seen_rounds.append(r)
            for key in ("comm", "wall"):
                if key not in ev:
                    errs.append("round %d: missing %r" % (r, key))
    return errs
