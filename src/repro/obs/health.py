"""On-device training-health scalars riding the fused round outputs.

``cohort_health`` runs INSIDE the already-compiled robust/plain round
bodies in ``core/cohort.py`` — a handful of reductions over arrays the
body already holds — so enabling it keeps dispatches/round at exactly 1
and never touches the factored path (zero dense merges).  Every value
is a replicated f32 scalar (partial sums are ``psum``-ed across the
client shards before normalization), safe to return with a replicated
``P()`` out-spec.

Signals (keys of the returned dict):

    update_norm       L2 norm of the aggregated global update — the
                      weighted FedAvg mean of per-client deltas
                      (send − round-start upload subtree), gated to 0 on
                      a void round.  Under ``factored_agg`` this is the
                      plain stacked-mean norm, i.e. a monitor of the raw
                      update mass, not of the rank-r re-projected
                      broadcast.
    client_norm_mean  mean over cohort rows of per-client delta L2 norm
                      — the per-client "grad norm" proxy: the full
                      local-steps round update, NOT a single micro-batch
                      gradient (a true per-step grad norm would need a
                      second output per scan step).  Ghost-padded rows
                      (non-divisible shard cohorts duplicate client 0)
                      are included in the mean/max.
    client_norm_max   max over cohort rows of the same norm.
    codec_err         L2 norm of (decoded − raw) upload across the
                      cohort: the codec's reconstruction error this
                      round; 0.0 when no codec.
    agg_weight_sum    Σ effective aggregation weights (staleness decay ×
                      on-time mask) — the "how much signal landed" dial.
    delivered         count of cohort rows with weight > 0.
    loss_mean         masked mean local training loss over
                      (client, local-step).

``host_health`` is the float64 numpy oracle the parity test compares
against (single-shard inputs).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

HEALTH_KEYS = ("update_norm", "client_norm_mean", "client_norm_max",
               "codec_err", "agg_weight_sum", "delivered", "loss_mean")


def _psum(x, axis_names):
    return jax.lax.psum(x, axis_names) if axis_names else x


def _pmax(x, axis_names):
    return jax.lax.pmax(x, axis_names) if axis_names else x


def _leaf_sq(leaf):
    """Per-client sum of squares: reduce every axis but the client axis."""
    x = leaf.astype(jnp.float32)
    return jnp.sum(x * x, axis=tuple(range(1, x.ndim)))


def cohort_health(send, ref, losses, agg_w, gate, *,
                  train_m=None, raw=None, decoded=None,
                  axis_names: Optional[Sequence[str]] = None
                  ) -> Dict[str, jnp.ndarray]:
    """All args are the round body's locals: ``send``/``ref`` stacked
    client trees (axis 0 = cohort row), ``losses`` (C, steps), ``agg_w``
    (C,), ``gate`` scalar, ``raw``/``decoded`` the pre/post-codec upload
    trees, ``axis_names`` the shard_map client axes (None off-mesh)."""
    # trace-time import: core.cohort imports this module, so pulling
    # aggregation at module scope would cycle through repro.core.__init__
    from repro.core.aggregation import fedavg_stacked
    an = tuple(axis_names) if axis_names else None
    delta = jax.tree.map(lambda s, r: s.astype(jnp.float32) - r.astype(jnp.float32),
                         send, ref)

    # aggregated-update norm: fedavg_stacked already psums its partial
    # sums under shard_map, so the mean tree is replicated — reduce local.
    agg = fedavg_stacked(delta, agg_w, axis_names=an)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(agg))
    update_norm = jnp.sqrt(sq) * gate.astype(jnp.float32)

    # per-client delta norms (includes ghost-padded rows)
    per_client_sq = sum(_leaf_sq(l) for l in jax.tree.leaves(delta))
    norms = jnp.sqrt(per_client_sq)
    n_local = jnp.float32(norms.shape[0])
    client_norm_mean = _psum(norms.sum(), an) / jnp.maximum(_psum(n_local, an), 1.0)
    client_norm_max = _pmax(norms.max(), an)

    if raw is not None and decoded is not None:
        err_sq = sum(_psum(jnp.sum(jnp.square(d.astype(jnp.float32)
                                              - r.astype(jnp.float32))), an)
                     for d, r in zip(jax.tree.leaves(decoded),
                                     jax.tree.leaves(raw)))
        codec_err = jnp.sqrt(err_sq)
    else:
        codec_err = jnp.float32(0.0)

    agg_weight_sum = _psum(agg_w.astype(jnp.float32).sum(), an)
    delivered = _psum((agg_w > 0).astype(jnp.float32).sum(), an)

    tm = jnp.ones((losses.shape[0],), jnp.float32) if train_m is None else train_m
    n_steps = jnp.float32(losses.shape[1]) if losses.ndim > 1 else jnp.float32(1.0)
    loss_sum = _psum(losses.astype(jnp.float32).sum(), an)
    loss_den = _psum(tm.astype(jnp.float32).sum(), an) * n_steps
    loss_mean = loss_sum / jnp.maximum(loss_den, 1.0)

    return {"update_norm": update_norm,
            "client_norm_mean": client_norm_mean,
            "client_norm_max": client_norm_max,
            "codec_err": codec_err,
            "agg_weight_sum": agg_weight_sum,
            "delivered": delivered,
            "loss_mean": loss_mean}


# ---------------------------------------------------------------------------
# float64 numpy oracle (parity test)
# ---------------------------------------------------------------------------


def host_health(send, ref, losses, agg_w, gate, *,
                train_m=None, raw=None, decoded=None) -> Dict[str, float]:
    """Single-shard numpy recomputation of ``cohort_health`` in float64."""
    send_l = [np.asarray(l, np.float64) for l in jax.tree.leaves(send)]
    ref_l = [np.asarray(l, np.float64) for l in jax.tree.leaves(ref)]
    w = np.asarray(agg_w, np.float64)
    losses = np.asarray(losses, np.float64)
    deltas = [s - r for s, r in zip(send_l, ref_l)]

    wsum = max(w.sum(), 1e-12)
    sq = 0.0
    for d in deltas:
        mean = np.tensordot(w, d, axes=(0, 0)) / wsum
        sq += float(np.sum(mean * mean))
    update_norm = float(np.sqrt(sq)) * float(gate)

    per_client = np.zeros(w.shape[0], np.float64)
    for d in deltas:
        per_client += d.reshape(d.shape[0], -1).__pow__(2).sum(axis=1)
    norms = np.sqrt(per_client)

    if raw is not None and decoded is not None:
        err = 0.0
        for dd, rr in zip(jax.tree.leaves(decoded), jax.tree.leaves(raw)):
            diff = np.asarray(dd, np.float64) - np.asarray(rr, np.float64)
            err += float(np.sum(diff * diff))
        codec_err = float(np.sqrt(err))
    else:
        codec_err = 0.0

    tm = np.ones(w.shape[0]) if train_m is None else np.asarray(train_m, np.float64)
    n_steps = float(losses.shape[1]) if losses.ndim > 1 else 1.0
    loss_mean = float(losses.sum()) / max(float(tm.sum()) * n_steps, 1.0)

    return {"update_norm": update_norm,
            "client_norm_mean": float(norms.mean()),
            "client_norm_max": float(norms.max()),
            "codec_err": codec_err,
            "agg_weight_sum": float(w.sum()),
            "delivered": float((w > 0).sum()),
            "loss_mean": loss_mean}
