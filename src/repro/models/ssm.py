"""Mamba-2 SSD (state-space duality) mixer.

Sequence mode uses the chunked matmul form (TPU-friendly: the intra-chunk
term is a masked batched GEMM for the MXU; the inter-chunk recurrence is a
short ``lax.scan`` over chunk states).  Decode mode is the O(1) recurrent
update.  ``repro.kernels.ssd_chunk`` implements the intra-chunk GEMM as a
Pallas kernel; this module is the jnp lowering/oracle path.

Factored-LoRA contract (the universal fused path): ``mamba_seq`` and
``mamba_decode`` take an optional ``lora`` side channel — a dict mirroring
the param leaves with ``{'a','b','mask'}`` factor dicts (``peft.init_lora``)
on ``in_proj`` and/or ``out_proj`` — plus ``scale`` (α/r) and ``backend``.
Targeted projections run ``peft.lora_proj``
(``y = x@W + scale·((x@A)@(mask·B))``) so the dense delta is never formed
and, under the cohort engine's client vmap, the frozen base stays UNBATCHED
while only the rank-r factors carry the client axis.  ``mamba_seq_sp`` (the
sequence-parallel shard_map path) deliberately does NOT take factors — its
in_specs replicate the raw weights — so ``blocks`` routes factored layers
through ``mamba_seq`` instead (``peft.has_factors`` gate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.norms import rmsnorm
from repro.models.peft import lora_proj
from repro.sharding import shard_map


def _lf(lora, key):
    """One leaf's factor dict from the mixer side channel (None-safe)."""
    return None if lora is None else lora.get(key)


def segsum(a):
    """a: (..., L) → (..., L, L) with out[i,j] = sum_{k=j+1..i} a_k (i≥j),
    -inf above the diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunk_scan(x, dt, a_coef, b_mat, c_mat, chunk: int, h0=None):
    """Chunked SSD scan.

    x:     (B, S, H, P)   per-head inputs
    dt:    (B, S, H)      post-softplus step sizes
    a_coef:(H,)           negative decay coefficients (= -exp(A_log))
    b_mat: (B, S, H, N)   input projections (groups already broadcast)
    c_mat: (B, S, H, N)   output projections
    Returns y (B, S, H, P), h_final (B, H, P, N).
    """
    b, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    s_orig = s
    if s % chunk:
        # pad with dt=0 positions: decay exp(0)=1, contribution dt·B·x = 0 —
        # state passes through unchanged, padded outputs are discarded.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk

    def resh(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, bc, cc = resh(x), resh(dt), resh(b_mat), resh(c_mat)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(hprev, inp):
        xk, dtk, bk, ck = inp                    # (b, L, h, ...)
        ad = (dtk.astype(jnp.float32)
              * a_coef.astype(jnp.float32)[None, None, :])   # (b, L, h)
        adt = ad.swapaxes(1, 2)                   # (b, h, L)
        cs = jnp.cumsum(adt, axis=-1)             # (b, h, L)
        # intra-chunk (masked attention-like term)
        ss = jnp.exp(segsum(adt))                 # (b, h, L, L)
        scores = jnp.einsum("blhn,bmhn->bhlm", ck.astype(jnp.float32),
                            bk.astype(jnp.float32))
        scores = scores * ss * dtk.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhlm,bmhp->blhp", scores, xk.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cs)                    # (b, h, L)
        y_inter = jnp.einsum("blhn,bhpn,bhl->blhp", ck.astype(jnp.float32),
                             hprev, decay_in)
        # state update
        total = cs[..., -1]                       # (b, h)
        decay_out = jnp.exp(total[..., None] - cs)            # (b, h, L)
        contrib = (bk.astype(jnp.float32)
                   * (dtk.astype(jnp.float32)
                      * decay_out.swapaxes(1, 2))[..., None])  # (b, L, h, n)
        hnew = (jnp.exp(total)[..., None, None] * hprev
                + jnp.einsum("blhn,blhp->bhpn", contrib, xk.astype(jnp.float32)))
        return hnew, (y_intra + y_inter)

    h_final, yc = jax.lax.scan(step, h0, (xc, dtc, bc, cc))
    y = yc.swapaxes(0, 1).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), h_final


def ssd_decode_step(xt, dtt, a_coef, bt, ct, hprev):
    """Single-token recurrence.  xt: (B,H,P); dtt: (B,H); bt/ct: (B,H,N);
    hprev: (B,H,P,N) → (y (B,H,P), hnew)."""
    ad = jnp.exp(dtt.astype(jnp.float32) * a_coef[None, :])     # (B,H)
    hnew = (ad[..., None, None] * hprev
            + jnp.einsum("bhp,bhn,bh->bhpn", xt.astype(jnp.float32),
                         bt.astype(jnp.float32), dtt.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", hnew, ct.astype(jnp.float32))
    return y.astype(xt.dtype), hnew


# ---------------------------------------------------------------------------
# Full mamba2 mixer (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype):
    d_in = cfg.expand * d_model
    h = d_in // cfg.headdim
    conv_dim = d_in + 2 * cfg.n_groups * cfg.state
    proj_out = 2 * d_in + 2 * cfg.n_groups * cfg.state + h
    ks = jax.random.split(key, 4)
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, proj_out))
                    * d_model ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim))
                   * cfg.conv_width ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": {"scale": jnp.zeros((d_in,), dtype)},
        "out_proj": (jax.random.normal(ks[2], (d_in, d_model))
                     * d_in ** -0.5).astype(dtype),
    }


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv.  xbc: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None]
              for i in range(width))
    return out + bias[None, None]


def _split_proj(zxbcdt, d_in, g_n, h):
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * g_n]
    dt_raw = zxbcdt[..., -h:]
    return z, xbc, dt_raw


def mamba_seq(x, p, cfg: SSMConfig, d_model: int, eps: float, h0=None,
              conv0=None, lora=None, scale: float = 1.0,
              backend: str = "jnp"):
    """Full-sequence mamba2 mixer.  Returns (y, (h_final, conv_state)).
    ``lora``/``scale``/``backend``: factored-LoRA side channel (module
    docstring) — in_proj/out_proj stay unmerged."""
    b, s, _ = x.shape
    d_in = cfg.expand * d_model
    h = d_in // cfg.headdim
    g_n = cfg.n_groups * cfg.state
    zxbcdt = lora_proj(x, p["in_proj"], _lf(lora, "in_proj"), scale=scale,
                       backend=backend)
    z, xbc, dt_raw = _split_proj(zxbcdt, d_in, g_n, h)
    if conv0 is not None:
        xbc_ext = jnp.concatenate([conv0, xbc], axis=1)
        conv_out = _causal_conv(xbc_ext, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    conv_state = jax.lax.dynamic_slice_in_dim(
        jnp.concatenate([jnp.zeros((b, cfg.conv_width - 1, xbc.shape[-1]),
                                   xbc.dtype), xbc], axis=1),
        s, cfg.conv_width - 1, axis=1)
    xbc = jax.nn.silu(conv_out)
    xs = xbc[..., :d_in].reshape(b, s, h, cfg.headdim)
    bmat = xbc[..., d_in:d_in + g_n].reshape(b, s, cfg.n_groups, cfg.state)
    cmat = xbc[..., d_in + g_n:].reshape(b, s, cfg.n_groups, cfg.state)
    rep = h // cfg.n_groups
    bmat = jnp.repeat(bmat, rep, axis=2)
    cmat = jnp.repeat(cmat, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    a_coef = -jnp.exp(p["a_log"])
    y, h_final = ssd_chunk_scan(xs, dt, a_coef, bmat, cmat, cfg.chunk, h0=h0)
    y = y + (p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, d_in)
    y = rmsnorm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                p["gate_norm"]["scale"], eps)
    y = lora_proj(y, p["out_proj"], _lf(lora, "out_proj"), scale=scale,
                  backend=backend)
    return y, (h_final, conv_state)


def mamba_decode(x, p, cfg: SSMConfig, d_model: int, eps: float, h_state,
                 conv_state, lora=None, scale: float = 1.0,
                 backend: str = "jnp"):
    """Single-token mamba2 step.  x: (B,1,d).  Returns (y, (h, conv))."""
    b = x.shape[0]
    d_in = cfg.expand * d_model
    h = d_in // cfg.headdim
    g_n = cfg.n_groups * cfg.state
    zxbcdt = lora_proj(x[:, 0], p["in_proj"], _lf(lora, "in_proj"),
                       scale=scale, backend=backend)
    z = zxbcdt[..., :d_in]
    xbc_t = zxbcdt[..., d_in:d_in + d_in + 2 * g_n]
    dt_raw = zxbcdt[..., -h:]
    # conv ring: conv_state holds the previous (W-1) inputs
    window = jnp.concatenate([conv_state, xbc_t[:, None]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    new_conv = window[:, 1:]
    xbc = jax.nn.silu(conv_out)
    xs = xbc[..., :d_in].reshape(b, h, cfg.headdim)
    bmat = xbc[..., d_in:d_in + g_n].reshape(b, cfg.n_groups, cfg.state)
    cmat = xbc[..., d_in + g_n:].reshape(b, cfg.n_groups, cfg.state)
    rep = h // cfg.n_groups
    bmat = jnp.repeat(bmat, rep, axis=1)
    cmat = jnp.repeat(cmat, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None])
    a_coef = -jnp.exp(p["a_log"])
    y, hnew = ssd_decode_step(xs, dt, a_coef, bmat, cmat, h_state)
    y = y + (p["d_skip"][None, :, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, d_in)
    y = rmsnorm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                p["gate_norm"]["scale"], eps)
    y = lora_proj(y, p["out_proj"], _lf(lora, "out_proj"), scale=scale,
                  backend=backend)
    return y[:, None], (hnew, new_conv)


# ---------------------------------------------------------------------------
# Sequence-parallel SSD (§Perf optimization B2 — recurrent-scan sharding)
# ---------------------------------------------------------------------------
#
# The TP layout forces every mamba layer to all-gather seq-sharded boundary
# activations before in_proj (and reduce them after) — the dominant
# collective for hybrid stacks.  But the SSD recurrence is associative: each
# device can scan its own sequence shard with h0=0, exchange only the tiny
# per-shard (decay, state) summaries (H·P·N floats), compute its incoming
# state with an exclusive prefix over devices, and add the linear correction
# term locally.  Activations stay seq-sharded through the entire layer; the
# only collectives are a (W-1)-token conv halo exchange and the state
# all-gather (~2 MB vs ~0.5 GB of activation gathers per layer).


def _sp_body(x, in_proj, conv_w, conv_b, a_log, d_skip, dt_bias, gate_scale,
             out_proj, *, cfg: SSMConfig, d_model: int, eps: float,
             model_axis: str, n_dev: int):
    b, s_loc, _ = x.shape
    d_in = cfg.expand * d_model
    h = d_in // cfg.headdim
    g_n = cfg.n_groups * cfg.state

    zxbcdt = x @ in_proj
    z, xbc, dt_raw = _split_proj(zxbcdt, d_in, g_n, h)

    # causal conv with halo from the previous device (ring shift)
    halo = xbc[:, -(cfg.conv_width - 1):, :]
    prev = jax.lax.ppermute(halo, model_axis,
                            [(i, i + 1) for i in range(n_dev - 1)])
    idx = jax.lax.axis_index(model_axis)
    prev = jnp.where(idx > 0, prev, jnp.zeros_like(prev))
    xbc_ext = jnp.concatenate([prev, xbc], axis=1)
    conv_out = _causal_conv(xbc_ext, conv_w, conv_b)[:, cfg.conv_width - 1:]
    xbc = jax.nn.silu(conv_out)

    xs = xbc[..., :d_in].reshape(b, s_loc, h, cfg.headdim)
    bmat = xbc[..., d_in:d_in + g_n].reshape(b, s_loc, cfg.n_groups, cfg.state)
    cmat = xbc[..., d_in + g_n:].reshape(b, s_loc, cfg.n_groups, cfg.state)
    rep = h // cfg.n_groups
    bmat = jnp.repeat(bmat, rep, axis=2)
    cmat = jnp.repeat(cmat, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + dt_bias[None, None])
    a_coef = -jnp.exp(a_log)

    # local scan from zero state → y_local + this shard's state contribution
    y, s_dev = ssd_chunk_scan(xs, dt, a_coef, bmat, cmat, cfg.chunk)

    # cross-device exclusive prefix over (decay, state)
    cs_full = jnp.cumsum(dt * a_coef[None, None, :], axis=1)   # (B,S_loc,H)
    d_dev = jnp.exp(cs_full[:, -1])                            # (B,H)
    d_all = jax.lax.all_gather(d_dev, model_axis)              # (M,B,H)
    s_all = jax.lax.all_gather(s_dev, model_axis)              # (M,B,H,P,N)

    def pscan(carry, js):
        dj, sj = js
        out = carry
        return dj[..., None, None] * carry + sj, out
    _, h_in_all = jax.lax.scan(pscan,
                               jnp.zeros_like(s_dev), (d_all, s_all))
    h_in = h_in_all[idx]                                       # (B,H,P,N)

    # linear correction: contribution of the incoming state to local outputs
    y_corr = jnp.einsum("blhn,bhpn,blh->blhp", cmat.astype(jnp.float32),
                        h_in, jnp.exp(cs_full))
    y = (y.astype(jnp.float32) + y_corr)
    y = y + d_skip[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s_loc, d_in).astype(x.dtype)
    y = rmsnorm((y.astype(jnp.float32)
                 * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                gate_scale, eps)
    return y @ out_proj


def mamba_seq_sp(x, p, cfg: SSMConfig, d_model: int, eps: float, meshctx):
    """Sequence-parallel mamba2 mixer: x (B, S, d) with S sharded over the
    model axis.  Weights are gathered (small) — activations never are."""
    import functools
    from jax.sharding import PartitionSpec as P

    msize = meshctx.model_size
    if msize <= 1 or x.shape[1] % msize != 0:
        return mamba_seq(x, p, cfg, d_model, eps)[0]
    batch_ax = meshctx.dim_axis(x.shape[0], meshctx.batch_axes)
    bspec = P(batch_ax, meshctx.model_axis, None)
    body = functools.partial(_sp_body, cfg=cfg, d_model=d_model, eps=eps,
                             model_axis=meshctx.model_axis, n_dev=msize)
    rep = P(None, None)
    return shard_map(
        body, mesh=meshctx.mesh,
        in_specs=(bspec, rep, rep, P(None), P(None), P(None), P(None),
                  P(None), rep),
        out_specs=bspec, check_vma=False,
    )(x, p["in_proj"], p["conv_w"], p["conv_b"], p["a_log"], p["d_skip"],
      p["dt_bias"], p["gate_norm"]["scale"], p["out_proj"])
