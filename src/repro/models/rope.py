"""Rotary position embeddings (functional, half-rotation convention)."""
import jax.numpy as jnp


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (...,) int32 → cos/sin of shape positions.shape + (head_dim/2,)."""
    half = head_dim // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    cos, sin = rope_cos_sin(positions, d, theta)
    # broadcast to (B, S, 1, D/2)
    while cos.ndim < x.ndim - 1:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
