"""Multi-head Latent Attention (DeepSeek-V2).

Sequence mode materializes per-head k/v from the compressed latent (fine with
remat); decode mode uses the *absorbed* formulation — q is projected into the
kv_lora latent space so attention runs directly against the compressed cache
(c_kv, k_rope), which is the whole point of MLA's small KV cache.

Factored-LoRA contract (the universal fused path): every entry point takes an
optional ``lora`` side channel — a dict mirroring the param leaves with
``{'a','b','mask'}`` factor dicts (``peft.init_lora``) on any of ``wq_a`` /
``wq_b`` / ``wkv_a`` / ``wkv_b`` / ``wo`` — plus ``scale`` (α/r) and
``backend``.  Targeted projections run ``peft.lora_proj``:

    y = x @ W + scale · ((x @ A) @ (mask · B))

so the dense (din, dout) delta is never formed and, under the cohort
engine's client-vmap, the frozen base stays UNBATCHED while only the rank-r
factors carry the client axis.  The one deliberate exception is absorbed
decode: ``mla_decode`` contracts q/ctx against ``wkv_b`` itself (not
``x @ W``), so ``wkv_b`` factors are merged into the LATENT-space weight
(kv_lora_rank × n_heads·(nope+v) — the same order as the factor's own B,
never a d_model² delta) via ``peft.effective_weight``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models import attention as attn
from repro.models.norms import rmsnorm
from repro.models.peft import effective_weight, lora_proj
from repro.models.rope import apply_rope


def _lf(lora, key):
    """One leaf's factor dict from the mixer side channel (None-safe)."""
    return None if lora is None else lora.get(key)


def init_mla(key, d_model: int, n_heads: int, cfg: MLAConfig, dtype):
    ks = jax.random.split(key, 5)
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    std = d_model ** -0.5
    return {
        "wq_a": (jax.random.normal(ks[0], (d_model, cfg.q_lora_rank)) * std).astype(dtype),
        "q_norm": {"scale": jnp.zeros((cfg.q_lora_rank,), dtype)},
        "wq_b": (jax.random.normal(ks[1], (cfg.q_lora_rank, n_heads * qk))
                 * cfg.q_lora_rank ** -0.5).astype(dtype),
        "wkv_a": (jax.random.normal(ks[2], (d_model, cfg.kv_lora_rank + cfg.rope_head_dim))
                  * std).astype(dtype),
        "kv_norm": {"scale": jnp.zeros((cfg.kv_lora_rank,), dtype)},
        "wkv_b": (jax.random.normal(ks[3], (cfg.kv_lora_rank,
                                            n_heads * (cfg.nope_head_dim + cfg.v_head_dim)))
                  * cfg.kv_lora_rank ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[4], (n_heads * cfg.v_head_dim, d_model))
               * (n_heads * cfg.v_head_dim) ** -0.5).astype(dtype),
    }


def _project_q(x, p, cfg: MLAConfig, n_heads: int, positions, rope_theta, eps,
               lora=None, scale: float = 1.0, backend: str = "jnp"):
    b, s, _ = x.shape
    cq = rmsnorm(lora_proj(x, p["wq_a"], _lf(lora, "wq_a"), scale=scale,
                           backend=backend), p["q_norm"]["scale"], eps)
    q = lora_proj(cq, p["wq_b"], _lf(lora, "wq_b"), scale=scale,
                  backend=backend).reshape(
        b, s, n_heads, cfg.nope_head_dim + cfg.rope_head_dim)
    q_nope, q_pe = q[..., :cfg.nope_head_dim], q[..., cfg.nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, rope_theta)
    return q_nope, q_pe


def _compress_kv(x, p, cfg: MLAConfig, positions, rope_theta, eps,
                 lora=None, scale: float = 1.0, backend: str = "jnp"):
    kv_a = lora_proj(x, p["wkv_a"], _lf(lora, "wkv_a"), scale=scale,
                     backend=backend)
    c_kv = rmsnorm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"]["scale"], eps)
    k_pe = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions, rope_theta)
    return c_kv, k_pe[..., 0, :]                       # (B,S,r), (B,S,rope_hd)


def mla_seq(x, p, cfg: MLAConfig, n_heads: int, positions, rope_theta: float,
            eps: float, *, causal: bool = True, impl: str = "auto",
            sparse_cfg=None, q_offset: int = 0, causal_skip: bool = False,
            lora=None, scale: float = 1.0, backend: str = "jnp"):
    """Full-sequence MLA (train / prefill).  Returns (y, (c_kv, k_pe)).
    ``lora``/``scale``/``backend``: the factored-LoRA side channel (module
    docstring) — every projection stays unmerged."""
    b, s, _ = x.shape
    q_nope, q_pe = _project_q(x, p, cfg, n_heads, positions, rope_theta, eps,
                              lora=lora, scale=scale, backend=backend)
    c_kv, k_pe = _compress_kv(x, p, cfg, positions, rope_theta, eps,
                              lora=lora, scale=scale, backend=backend)
    kv = lora_proj(c_kv, p["wkv_b"], _lf(lora, "wkv_b"), scale=scale,
                   backend=backend).reshape(
        b, s, n_heads, cfg.nope_head_dim + cfg.v_head_dim)
    k_nope, v = kv[..., :cfg.nope_head_dim], kv[..., cfg.nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None],
                                  (b, s, n_heads, cfg.rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    if impl == "sparse" and sparse_cfg is not None:
        y = attn.block_sparse_attention(q, k, v, sparse_cfg, q_offset=q_offset)
    elif impl == "dense" or s <= 2048:
        y = attn.dense_attention(q, k, v, causal=causal, q_offset=q_offset)
    elif causal and causal_skip:
        y = attn.chunked_attention_pairs(q, k, v, causal=True,
                                         q_offset=q_offset)
    else:
        y = attn.chunked_attention(q, k, v, causal=causal, q_offset=q_offset)
    y = lora_proj(y.reshape(b, s, n_heads * cfg.v_head_dim), p["wo"],
                  _lf(lora, "wo"), scale=scale, backend=backend)
    return y, (c_kv, k_pe)


def mla_decode(x, p, cfg: MLAConfig, n_heads: int, pos, rope_theta: float,
               eps: float, ckv_cache, kpe_cache, *, sparse_cfg=None,
               lora=None, scale: float = 1.0, backend: str = "jnp"):
    """Absorbed-MLA decode.  x: (B,1,d); caches: (B,Sc,r) / (B,Sc,rope_hd);
    ``pos``: traced scalar — index the new token was written at.
    Caller must have already written the new (c_kv, k_pe) at ``pos``.
    ``wkv_b`` factors merge into the latent-space weight here
    (``peft.effective_weight`` — see module docstring); q/o projections stay
    factored."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos)
    q_nope, q_pe = _project_q(x, p, cfg, n_heads, positions, rope_theta, eps,
                              lora=lora, scale=scale, backend=backend)
    r = cfg.kv_lora_rank
    wkv_b = effective_weight(p["wkv_b"], _lf(lora, "wkv_b"), scale).reshape(
        r, n_heads, cfg.nope_head_dim + cfg.v_head_dim)
    wk_b, wv_b = wkv_b[..., :cfg.nope_head_dim], wkv_b[..., cfg.nope_head_dim:]

    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    att_scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    logits = (jnp.einsum("bhr,btr->bht", q_abs, ckv_cache.astype(jnp.float32))
              + jnp.einsum("bhp,btp->bht", q_pe[:, 0].astype(jnp.float32),
                           kpe_cache.astype(jnp.float32))) * att_scale
    sc = ckv_cache.shape[1]
    slot = jnp.arange(sc)
    allowed = slot <= pos
    if sparse_cfg is not None:
        bs = sparse_cfg.block_size
        blk, qblk = slot // bs, pos // bs
        a = (blk < sparse_cfg.sink_blocks)
        a |= blk > qblk - sparse_cfg.local_blocks
        a |= (blk % sparse_cfg.stride) == 0
        allowed &= a
    logits = jnp.where(allowed[None, None], logits, attn.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", probs, ckv_cache.astype(jnp.float32))
    v_out = jnp.einsum("bhr,rhv->bhv", ctx, wv_b.astype(jnp.float32))
    y = lora_proj(v_out.reshape(b, 1, n_heads * cfg.v_head_dim).astype(x.dtype),
                  p["wo"], _lf(lora, "wo"), scale=scale, backend=backend)
    return y
