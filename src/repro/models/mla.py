"""Multi-head Latent Attention (DeepSeek-V2).

Sequence mode materializes per-head k/v from the compressed latent (fine with
remat); decode mode uses the *absorbed* formulation — q is projected into the
kv_lora latent space so attention runs directly against the compressed cache
(c_kv, k_rope), which is the whole point of MLA's small KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models import attention as attn
from repro.models.norms import rmsnorm
from repro.models.rope import apply_rope


def init_mla(key, d_model: int, n_heads: int, cfg: MLAConfig, dtype):
    ks = jax.random.split(key, 5)
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    std = d_model ** -0.5
    return {
        "wq_a": (jax.random.normal(ks[0], (d_model, cfg.q_lora_rank)) * std).astype(dtype),
        "q_norm": {"scale": jnp.zeros((cfg.q_lora_rank,), dtype)},
        "wq_b": (jax.random.normal(ks[1], (cfg.q_lora_rank, n_heads * qk))
                 * cfg.q_lora_rank ** -0.5).astype(dtype),
        "wkv_a": (jax.random.normal(ks[2], (d_model, cfg.kv_lora_rank + cfg.rope_head_dim))
                  * std).astype(dtype),
        "kv_norm": {"scale": jnp.zeros((cfg.kv_lora_rank,), dtype)},
        "wkv_b": (jax.random.normal(ks[3], (cfg.kv_lora_rank,
                                            n_heads * (cfg.nope_head_dim + cfg.v_head_dim)))
                  * cfg.kv_lora_rank ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[4], (n_heads * cfg.v_head_dim, d_model))
               * (n_heads * cfg.v_head_dim) ** -0.5).astype(dtype),
    }


def _project_q(x, p, cfg: MLAConfig, n_heads: int, positions, rope_theta, eps):
    b, s, _ = x.shape
    cq = rmsnorm(x @ p["wq_a"], p["q_norm"]["scale"], eps)
    q = (cq @ p["wq_b"]).reshape(b, s, n_heads, cfg.nope_head_dim + cfg.rope_head_dim)
    q_nope, q_pe = q[..., :cfg.nope_head_dim], q[..., cfg.nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, rope_theta)
    return q_nope, q_pe


def _compress_kv(x, p, cfg: MLAConfig, positions, rope_theta, eps):
    kv_a = x @ p["wkv_a"]
    c_kv = rmsnorm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"]["scale"], eps)
    k_pe = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions, rope_theta)
    return c_kv, k_pe[..., 0, :]                       # (B,S,r), (B,S,rope_hd)


def mla_seq(x, p, cfg: MLAConfig, n_heads: int, positions, rope_theta: float,
            eps: float, *, causal: bool = True, impl: str = "auto",
            sparse_cfg=None, q_offset: int = 0, causal_skip: bool = False):
    """Full-sequence MLA (train / prefill).  Returns (y, (c_kv, k_pe))."""
    b, s, _ = x.shape
    q_nope, q_pe = _project_q(x, p, cfg, n_heads, positions, rope_theta, eps)
    c_kv, k_pe = _compress_kv(x, p, cfg, positions, rope_theta, eps)
    kv = (c_kv @ p["wkv_b"]).reshape(
        b, s, n_heads, cfg.nope_head_dim + cfg.v_head_dim)
    k_nope, v = kv[..., :cfg.nope_head_dim], kv[..., cfg.nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None],
                                  (b, s, n_heads, cfg.rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    if impl == "sparse" and sparse_cfg is not None:
        y = attn.block_sparse_attention(q, k, v, sparse_cfg, q_offset=q_offset)
    elif impl == "dense" or s <= 2048:
        y = attn.dense_attention(q, k, v, causal=causal, q_offset=q_offset)
    elif causal and causal_skip:
        y = attn.chunked_attention_pairs(q, k, v, causal=True,
                                         q_offset=q_offset)
    else:
        y = attn.chunked_attention(q, k, v, causal=causal, q_offset=q_offset)
    y = y.reshape(b, s, n_heads * cfg.v_head_dim) @ p["wo"]
    return y, (c_kv, k_pe)


def mla_decode(x, p, cfg: MLAConfig, n_heads: int, pos, rope_theta: float,
               eps: float, ckv_cache, kpe_cache, *, sparse_cfg=None):
    """Absorbed-MLA decode.  x: (B,1,d); caches: (B,Sc,r) / (B,Sc,rope_hd);
    ``pos``: traced scalar — index the new token was written at.
    Caller must have already written the new (c_kv, k_pe) at ``pos``."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos)
    q_nope, q_pe = _project_q(x, p, cfg, n_heads, positions, rope_theta, eps)
    r = cfg.kv_lora_rank
    wkv_b = p["wkv_b"].reshape(r, n_heads, cfg.nope_head_dim + cfg.v_head_dim)
    wk_b, wv_b = wkv_b[..., :cfg.nope_head_dim], wkv_b[..., cfg.nope_head_dim:]

    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = (cfg.nope_head_dim + cfg.rope_head_dim) ** -0.5
    logits = (jnp.einsum("bhr,btr->bht", q_abs, ckv_cache.astype(jnp.float32))
              + jnp.einsum("bhp,btp->bht", q_pe[:, 0].astype(jnp.float32),
                           kpe_cache.astype(jnp.float32))) * scale
    sc = ckv_cache.shape[1]
    slot = jnp.arange(sc)
    allowed = slot <= pos
    if sparse_cfg is not None:
        bs = sparse_cfg.block_size
        blk, qblk = slot // bs, pos // bs
        a = (blk < sparse_cfg.sink_blocks)
        a |= blk > qblk - sparse_cfg.local_blocks
        a |= (blk % sparse_cfg.stride) == 0
        allowed &= a
    logits = jnp.where(allowed[None, None], logits, attn.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", probs, ckv_cache.astype(jnp.float32))
    v_out = jnp.einsum("bhr,rhv->bhv", ctx, wv_b.astype(jnp.float32))
    y = v_out.reshape(b, 1, n_heads * cfg.v_head_dim).astype(x.dtype) @ p["wo"]
    return y
