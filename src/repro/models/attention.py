"""Attention cores.

Four execution paths, all GQA-aware (q: (B,S,H,hd); k/v: (B,Sk,K,hd), H=K*G):

* ``dense_attention``   — masked softmax einsum; short sequences & oracles.
* ``chunked_attention`` — flash-style: scan over q blocks, inner scan over kv
  blocks with a running (max, denom, acc).  O(block) memory, used for long
  training/prefill sequences.  ``causal_skip`` optionally skips kv blocks
  entirely above the diagonal (HLO-FLOP reduction — see EXPERIMENTS.md §Perf).
* ``block_sparse_attention`` — the paper's sparse-attention device adapted to
  TPU: a *static* block pattern (sink blocks + local band + strided global
  blocks).  Implemented gather-style: each q block gathers only its active kv
  blocks, so compiled FLOPs are sub-quadratic (O(S · A · block)), not merely
  masked.
* ``decode_attention``  — one query token against a (possibly ring-buffered)
  KV cache with position/window/sparse masking.

The Pallas TPU kernels in ``repro.kernels`` implement the same contracts; the
functions here are the jnp lowering path (CPU dry-run) and the oracles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SparseAttnConfig

NEG_INF = -1e30


def _split_gqa(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def make_mask(sq: int, sk: int, *, causal: bool, window: int = 0,
              q_offset=0):
    """(sq, sk) boolean 'allowed' mask.  q_offset may be traced."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    allowed = jnp.ones((sq, sk), dtype=bool)
    if causal:
        allowed &= kpos <= qpos
    if window > 0:
        allowed &= kpos > qpos - window
    return allowed


def dense_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, mask=None):
    b, sq, h, d = q.shape
    n_kv = k.shape[2]
    qg = _split_gqa(q, n_kv) * (d ** -0.5)
    logits = jnp.einsum("bsKgd,btKd->bKgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    if mask is None:
        mask = make_mask(sq, k.shape[1], causal=causal, window=window,
                         q_offset=q_offset)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bKgst,btKd->bsKgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style)
# ---------------------------------------------------------------------------


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_offset=0, q_block: int = 512, kv_block: int = 1024,
                      causal_skip: bool = False):
    """Flash-style attention: outer scan over q blocks, inner scan over kv
    blocks, online softmax.  ``causal_skip`` computes, for each q block, only
    the kv blocks at or below the diagonal (saves ~2x FLOPs for causal)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    n_kv = k.shape[2]
    dv = v.shape[-1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, q_block, sk, kv_block)
    nq, nk = sq // q_block, sk // kv_block
    g = h // n_kv

    qg = _split_gqa(q, n_kv).astype(jnp.float32) * (d ** -0.5)
    qb = qg.reshape(b, nq, q_block, n_kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.astype(jnp.float32).reshape(b, nk, kv_block, n_kv, d)
    vb = v.astype(jnp.float32).reshape(b, nk, kv_block, n_kv, dv)

    kpos_all = jnp.arange(sk).reshape(nk, kv_block)

    def q_step(_, qi_q):
        qi, qblk = qi_q  # qblk: (b, K, g, q_block, d)
        qpos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, kj_kv):
            m, l, acc = carry
            kj, kblk, vblk = kj_kv
            logits = jnp.einsum("bKgqd,bkKd->bKgqk", qblk, kblk)
            kpos = kpos_all[kj]
            allowed = jnp.ones((q_block, kv_block), dtype=bool)
            if causal:
                allowed &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                allowed &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.where(allowed[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bKgqk,bkKd->bKgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, n_kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_block, dv), jnp.float32)

        if causal_skip and causal and q_offset is not None and isinstance(q_offset, int):
            # only kv blocks whose start is <= last q position of this block
            # (static bound per q block via mask over a dynamic slice length is
            # not possible with scan; instead use fori_loop with traced bound)
            n_needed = (q_offset + (qi + 1) * q_block + kv_block - 1) // kv_block
            n_needed = jnp.minimum(n_needed, nk)

            def body(j, carry):
                out, _ = kv_step(carry, (j, kb[:, j], vb[:, j]))
                return out

            m, l, acc = jax.lax.fori_loop(0, n_needed, body, (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.arange(nk), kb.transpose(1, 0, 2, 3, 4),
                 vb.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, yb = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    # yb: (nq, b, K, g, q_block, dv) → (b, sq, h, dv)
    y = yb.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, dv)
    return y.astype(q.dtype)


# ---------------------------------------------------------------------------
# Block-sparse (the paper's technique)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def sparse_block_table(n_q_blocks: int, n_kv_blocks: int,
                       cfg: SparseAttnConfig, q_block_offset: int = 0):
    """Static (numpy) table of active kv-block indices per q block.

    Active set for absolute q block ``qi``: sink blocks [0, sink), local band
    (qi-local, qi], and strided global blocks {j : j % stride == 0, j < qi}.
    Returns (idx, valid): both (n_q_blocks, A)."""
    a_max = cfg.sink_blocks + cfg.local_blocks + int(np.ceil(n_kv_blocks / cfg.stride))
    idx = np.zeros((n_q_blocks, a_max), dtype=np.int32)
    valid = np.zeros((n_q_blocks, a_max), dtype=bool)
    for i in range(n_q_blocks):
        qi = i + q_block_offset
        active = set(range(min(cfg.sink_blocks, n_kv_blocks)))
        lo = max(0, qi - cfg.local_blocks + 1)
        active |= set(range(lo, min(qi + 1, n_kv_blocks)))
        active |= {j for j in range(0, min(qi + 1, n_kv_blocks), cfg.stride)}
        active = sorted(active)[:a_max]
        idx[i, : len(active)] = active
        valid[i, : len(active)] = True
    return idx, valid


def block_sparse_attention(q, k, v, cfg: SparseAttnConfig, *, q_offset: int = 0):
    """Causal block-sparse attention.  Gathers only active kv blocks per q
    block → compiled FLOPs are O(S·A·block), sub-quadratic."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    n_kv = k.shape[2]
    g = h // n_kv
    bs = cfg.block_size
    assert sq % bs == 0 and sk % bs == 0, (sq, sk, bs)
    nq, nk = sq // bs, sk // bs
    idx_np, valid_np = sparse_block_table(nq, nk, cfg, q_offset // bs)
    idx = jnp.asarray(idx_np)
    valid = jnp.asarray(valid_np)
    a = idx.shape[1]

    qg = _split_gqa(q, n_kv).astype(jnp.float32) * (d ** -0.5)
    qb = qg.reshape(b, nq, bs, n_kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.astype(jnp.float32).reshape(b, nk, bs, n_kv, d)
    vb = v.astype(jnp.float32).reshape(b, nk, bs, n_kv, d)

    def q_step(_, inputs):
        qi, qblk, blk_idx, blk_valid = inputs
        # gather active kv blocks: (b, A, bs, K, d)
        kg = jnp.take(kb, blk_idx, axis=1)
        vg = jnp.take(vb, blk_idx, axis=1)
        logits = jnp.einsum("bKgqd,bakKd->bKgqak", qblk, kg)
        qpos = q_offset + qi * bs + jnp.arange(bs)
        kpos = blk_idx[:, None] * bs + jnp.arange(bs)[None, :]
        allowed = (kpos[None] <= qpos[:, None, None]) & blk_valid[None, :, None]
        logits = jnp.where(allowed[None, None, None], logits, NEG_INF)
        flat = logits.reshape(*logits.shape[:-2], a * bs)
        probs = jax.nn.softmax(flat, axis=-1).reshape(logits.shape)
        out = jnp.einsum("bKgqak,bakKd->bKgqd", probs, vg)
        return None, out

    _, yb = jax.lax.scan(q_step, None, (jnp.arange(nq), qb, idx, valid))
    y = yb.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d)
    return y.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single query vs cache)
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     sparse: Optional[SparseAttnConfig] = None,
                     ring: bool = False):
    """q: (B,1,H,hd); caches: (B,Sc,K,hd); cache_len: traced scalar = number
    of valid positions INCLUDING the token just written.

    ``ring=True`` means the cache is a ring buffer of size Sc (window cache):
    all slots < min(cache_len, Sc) are valid and in-window by construction.
    ``sparse`` applies the static block pattern as a position mask (the
    gather-based saving at decode is a §Perf optimization)."""
    b, _, h, d = q.shape
    sc = k_cache.shape[1]
    n_kv = k_cache.shape[2]
    g = h // n_kv
    qg = q.reshape(b, n_kv, g, d).astype(jnp.float32) * (d ** -0.5)
    logits = jnp.einsum("bKgd,btKd->bKgt", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(sc)
    if ring:
        allowed = pos < jnp.minimum(cache_len, sc)
    else:
        allowed = pos < cache_len
        if window > 0:
            allowed &= pos > cache_len - 1 - window
        if sparse is not None:
            bs = sparse.block_size
            blk = pos // bs
            qblk = (cache_len - 1) // bs
            a = (blk < sparse.sink_blocks)
            a |= blk > qblk - sparse.local_blocks
            a |= (blk % sparse.stride) == 0
            allowed &= a
    logits = jnp.where(allowed[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bKgt,btKd->bKgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Causal block-skip chunked attention (§Perf optimization A)
# ---------------------------------------------------------------------------


def chunked_attention_pairs(q, k, v, *, causal: bool = True, window: int = 0,
                            q_offset: int = 0, q_block: int = 512,
                            kv_block: int = 512):
    """Flash-style attention that enumerates only the (q-block, kv-block)
    pairs at or below the causal diagonal (and inside the window), as a
    single static scan over valid pairs.

    vs ``chunked_attention`` (which scans ALL kv blocks and masks): compiled
    FLOPs drop from nq·nk to nq(nq+1)/2 block-GEMMs (~2× for causal), the
    structure stays a static scan (differentiable, and trip counts remain
    visible to jaxpr cost analysis).  This is the beyond-paper optimization
    recorded in EXPERIMENTS.md §Perf."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    n_kv = k.shape[2]
    dv = v.shape[-1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0
    nq, nk = sq // q_block, sk // kv_block
    g = h // n_kv

    pairs = []
    for i in range(nq):
        hi = (q_offset + (i + 1) * q_block - 1) // kv_block if causal else nk - 1
        hi = min(hi, nk - 1)
        lo = 0
        if window > 0:
            lo = max(0, (q_offset + i * q_block - window) // kv_block)
        for j in range(lo, hi + 1):
            pairs.append((i, j))
    pi = jnp.asarray(np.array([p[0] for p in pairs], np.int32))
    pj = jnp.asarray(np.array([p[1] for p in pairs], np.int32))

    qg = _split_gqa(q, n_kv).astype(jnp.float32) * (d ** -0.5)
    qb = qg.reshape(b, nq, q_block, n_kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.astype(jnp.float32).reshape(b, nk, kv_block, n_kv, d) \
        .transpose(1, 0, 2, 3, 4)
    vb = v.astype(jnp.float32).reshape(b, nk, kv_block, n_kv, dv) \
        .transpose(1, 0, 2, 3, 4)

    m0 = jnp.full((nq, b, n_kv, g, q_block), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, n_kv, g, q_block), jnp.float32)
    a0 = jnp.zeros((nq, b, n_kv, g, q_block, dv), jnp.float32)

    def step(carry, ij):
        m, l, acc = carry
        i, j = ij
        qblk = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
        logits = jnp.einsum("bKgqd,bkKd->bKgqk", qblk, kblk)
        qpos = q_offset + i * q_block + jnp.arange(q_block)
        kpos = j * kv_block + jnp.arange(kv_block)
        allowed = jnp.ones((q_block, kv_block), bool)
        if causal:
            allowed &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            allowed &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(allowed[None, None, None], logits, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(mi, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(-1)
        a_new = ai * corr[..., None] + jnp.einsum("bKgqk,bkKd->bKgqd", p, vblk)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (pi, pj))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    y = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, dv)
    return y.astype(q.dtype)


# ---------------------------------------------------------------------------
# Gather-based block-sparse decode (§Perf optimization C — the paper's
# sparse attention applied to long-context serving)
# ---------------------------------------------------------------------------


def sparse_gather_decode(q, k_cache, v_cache, pos, cfg):
    """Decode one token reading ONLY the active kv blocks of the paper's
    sparse pattern (sinks + local band + strided global) — HBM traffic per
    token drops from the full cache to the active fraction.

    q: (B,1,H,hd); caches: (B,Sc,K,hd); pos: traced scalar (token written at
    ``pos``; cache_len = pos+1)."""
    b, _, h, d = q.shape
    sc = k_cache.shape[1]
    n_kv = k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // n_kv
    bs = cfg.block_size
    assert sc % bs == 0
    n_blocks = sc // bs
    n_strided = max(1, n_blocks // cfg.stride)
    qblk = pos // bs

    sink_idx = jnp.arange(cfg.sink_blocks)
    local_idx = qblk - cfg.local_blocks + 1 + jnp.arange(cfg.local_blocks)
    strided_idx = jnp.arange(n_strided) * cfg.stride
    # validity + de-dup (a block must be counted once in the softmax):
    sink_ok = sink_idx <= qblk
    local_ok = (local_idx >= 0) & (local_idx >= cfg.sink_blocks) \
        & (local_idx <= qblk)
    strided_ok = (strided_idx >= cfg.sink_blocks) \
        & (strided_idx < qblk - cfg.local_blocks + 1)
    idx = jnp.concatenate([sink_idx, jnp.clip(local_idx, 0, n_blocks - 1),
                           strided_idx])
    ok = jnp.concatenate([sink_ok, local_ok, strided_ok])

    kb = k_cache.reshape(b, n_blocks, bs, n_kv, d)
    vb = v_cache.reshape(b, n_blocks, bs, n_kv, dv)
    kg = jnp.take(kb, idx, axis=1)          # (B, A, bs, K, d)
    vg = jnp.take(vb, idx, axis=1)

    qg = q.reshape(b, n_kv, g, d).astype(jnp.float32) * (d ** -0.5)
    logits = jnp.einsum("bKgd,bakKd->bKgak", qg, kg.astype(jnp.float32))
    kpos = idx[:, None] * bs + jnp.arange(bs)[None, :]
    allowed = (kpos <= pos) & ok[:, None]
    logits = jnp.where(allowed[None, None, None], logits, NEG_INF)
    a = idx.shape[0]
    flat = logits.reshape(b, n_kv, g, a * bs)
    probs = jax.nn.softmax(flat, axis=-1).reshape(logits.shape)
    out = jnp.einsum("bKgak,bakKd->bKgd", probs, vg.astype(jnp.float32))
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Sparse KV cache (§Perf optimization C — the paper's sparse attention as a
# cache ARCHITECTURE)
# ---------------------------------------------------------------------------
#
# Under the static block-sparse pattern, a position is ever attended again
# only if it lies in a sink/strided block or within the trailing local band.
# So the decode cache needs just: (i) a persistent region holding the
# sink+strided blocks (≈ S/stride slots), and (ii) a ring buffer of the last
# (local+1) blocks.  Cache memory AND per-token HBM reads shrink ~stride×,
# reads are contiguous (no dynamic gather → no cross-shard collectives), and
# the realized pattern is the paper's pattern with a (local+1)-block band.


def sparse_kv_layout(seq_len: int, cfg: SparseAttnConfig):
    """Static layout: persistent block list + block→slot lookup + ring size."""
    bs = cfg.block_size
    nb = -(-seq_len // bs)
    pers_blocks = sorted(set(range(min(cfg.sink_blocks, nb)))
                         | set(range(0, nb, cfg.stride)))
    block2slot = np.full((nb,), -1, np.int32)
    for slot, blk in enumerate(pers_blocks):
        block2slot[blk] = slot
    ring_blocks = cfg.local_blocks + 1
    return (np.asarray(pers_blocks, np.int32), block2slot,
            ring_blocks * bs, len(pers_blocks) * bs)


def sparse_kv_write(cache, k_new, v_new, pos, cfg: SparseAttnConfig,
                    seq_len: int):
    """Write one token (B,1,K,hd) into {k_pers,v_pers,k_ring,v_ring}."""
    bs = cfg.block_size
    pers_blocks, block2slot, ring_slots, n_pers = sparse_kv_layout(seq_len, cfg)
    b2s = jnp.asarray(block2slot)
    blk = pos // bs
    pslot_blk = b2s[blk]
    pers_idx = jnp.where(pslot_blk >= 0, pslot_blk * bs + pos % bs, n_pers)
    out = dict(cache)
    out["k_pers"] = cache["k_pers"].at[:, pers_idx].set(
        k_new[:, 0].astype(cache["k_pers"].dtype), mode="drop")
    out["v_pers"] = cache["v_pers"].at[:, pers_idx].set(
        v_new[:, 0].astype(cache["v_pers"].dtype), mode="drop")
    rslot = pos % ring_slots
    out["k_ring"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k_ring"], k_new.astype(cache["k_ring"].dtype), rslot, axis=1)
    out["v_ring"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v_ring"], v_new.astype(cache["v_ring"].dtype), rslot, axis=1)
    return out


def sparse_kv_decode(q, cache, pos, cfg: SparseAttnConfig, seq_len: int):
    """Attend over the sparse cache.  q: (B,1,H,hd) → (B,1,H,hd)."""
    bs = cfg.block_size
    pers_blocks, _, ring_slots, n_pers = sparse_kv_layout(seq_len, cfg)
    b, _, h, d = q.shape
    n_kv = cache["k_pers"].shape[2]
    g = h // n_kv
    qblk = pos // bs
    qg = q.reshape(b, n_kv, g, d).astype(jnp.float32) * (d ** -0.5)

    # persistent region: slot → absolute position (static formula)
    slot_blk = jnp.asarray(np.repeat(pers_blocks, bs))
    slot_pos = jnp.asarray(np.repeat(pers_blocks, bs) * bs
                           + np.tile(np.arange(bs), len(pers_blocks)))
    pers_ok = (slot_pos <= pos) & (slot_blk <= qblk - cfg.local_blocks - 1)
    lp_ = jnp.einsum("bKgd,btKd->bKgt", qg,
                     cache["k_pers"].astype(jnp.float32))
    lp_ = jnp.where(pers_ok[None, None, None], lp_, NEG_INF)

    # ring region: slot r holds the largest position ≤ pos with p%ring == r
    r = jnp.arange(ring_slots)
    rpos = (pos // ring_slots) * ring_slots + r
    rpos = jnp.where(rpos > pos, rpos - ring_slots, rpos)
    # block-aligned band: ring supplies exactly blocks (qblk-local ... qblk],
    # persistent region everything at or below qblk-local-1 — disjoint union
    ring_ok = (rpos >= 0) & (rpos >= (qblk - cfg.local_blocks) * bs)
    lr_ = jnp.einsum("bKgd,btKd->bKgt", qg,
                     cache["k_ring"].astype(jnp.float32))
    lr_ = jnp.where(ring_ok[None, None, None], lr_, NEG_INF)

    # merge the two regions by partial-softmax stats — no concat of the
    # (seq-sharded) persistent logits with the (replicated) ring logits,
    # so SPMD reduces each region independently (tiny collectives).
    def stats(lg, vals):
        m = lg.max(-1, keepdims=True)
        p = jnp.exp(lg - m)
        l = p.sum(-1, keepdims=True)
        acc = jnp.einsum("bKgt,btKd->bKgd", p, vals.astype(jnp.float32))
        return m[..., 0], l[..., 0], acc

    m1, l1, a1 = stats(lp_, cache["v_pers"])
    m2, l2, a2 = stats(lr_, cache["v_ring"])
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    out = (a1 * c1[..., None] + a2 * c2[..., None]) / \
        jnp.maximum(l1 * c1 + l2 * c2, 1e-30)[..., None]
    return out.reshape(b, 1, h, d).astype(q.dtype)
