"""Dense feed-forward layers (bias-free; see DESIGN.md deviations)."""
import jax
import jax.numpy as jnp


def act_fn(name: str):
    if name in ("swiglu",):
        return jax.nn.silu
    return jax.nn.gelu


def mlp(x, params, act: str, lora=None, scale: float = 1.0,
        backend: str = "jnp"):
    """swiglu/geglu: act(x·Wg) * (x·Wu) · Wd ;  gelu: act(x·Wu) · Wd.

    ``lora`` is an optional factor subtree mirroring ``params`` (see
    ``peft.lora_proj``): each projection runs factored, never forming the
    dense delta."""
    if lora is None:
        if act in ("swiglu", "geglu"):
            h = act_fn(act)(x @ params["wg"]) * (x @ params["wu"])
        else:
            h = act_fn(act)(x @ params["wu"])
        return h @ params["wd"]
    from repro.models.peft import lora_proj
    proj = lambda t, name: lora_proj(t, params[name], lora.get(name),
                                     scale=scale, backend=backend)
    if act in ("swiglu", "geglu"):
        h = act_fn(act)(proj(x, "wg")) * proj(x, "wu")
    else:
        h = act_fn(act)(proj(x, "wu"))
    return proj(h, "wd")


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = d_model ** -0.5
    std_out = d_ff ** -0.5
    p = {
        "wu": (jax.random.normal(k2, (d_model, d_ff)) * std_in).astype(dtype),
        "wd": (jax.random.normal(k3, (d_ff, d_model)) * std_out).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["wg"] = (jax.random.normal(k1, (d_model, d_ff)) * std_in).astype(dtype)
    return p
