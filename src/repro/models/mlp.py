"""Dense feed-forward layers (bias-free; see DESIGN.md deviations)."""
import jax
import jax.numpy as jnp


def act_fn(name: str):
    if name in ("swiglu",):
        return jax.nn.silu
    return jax.nn.gelu


def mlp(x, params, act: str):
    """swiglu/geglu: act(x·Wg) * (x·Wu) · Wd ;  gelu: act(x·Wu) · Wd."""
    if act in ("swiglu", "geglu"):
        h = act_fn(act)(x @ params["wg"]) * (x @ params["wu"])
    else:
        h = act_fn(act)(x @ params["wu"])
    return h @ params["wd"]


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = d_model ** -0.5
    std_out = d_ff ** -0.5
    p = {
        "wu": (jax.random.normal(k2, (d_model, d_ff)) * std_in).astype(dtype),
        "wd": (jax.random.normal(k3, (d_ff, d_model)) * std_out).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["wg"] = (jax.random.normal(k1, (d_model, d_ff)) * std_in).astype(dtype)
    return p
