"""Parameter-efficient fine-tuning: LoRA + bottleneck adapters.

PFTT (paper §IV-D) composes both: *universal adapters* (aggregated globally)
and *local LoRA* (kept on-client).  PFIT (paper §IV-C) uses last-K-layer
unfreezing with a head-structured sparsity mask over attention parameters.

Representation choices:
* LoRA factors mirror targeted 2-D (or stacked 3-D) weight leaves:
  ``W (…, din, dout) → A (…, din, r), B (…, r, dout)``, with a per-repeat
  enable mask so clients can LoRA only their last-n layers ("10-12 local
  LoRAs based on local resources").
* **Factored execution contract** (the default hot path): the lora tree is
  threaded through the model forward as a *side channel* next to ``params``
  and every targeted projection computes

      y = x @ W + (α/r) · ((x @ A) @ (mask · B))        # ``lora_proj``

  so the dense ``(din, dout)`` delta is never formed, the frozen base ``W``
  stays UNBATCHED under the cohort engine's client-vmap (only the rank-r
  factors carry the client axis), and autodiff produces factor gradients
  directly.  ``Model.{lm_loss,cls_loss,forward,prefill,decode_step}`` all
  accept ``lora=``/``lora_scale=``; ``lora_proj(backend="pallas")`` lowers
  the projection to the fused ``repro.kernels.lora_fused`` kernel (the
  serving path).  Layer masks ride along the layer scan as ``(repeats,1,1)``
  leaves.
* ``apply_lora`` (materialize ``W + (α/r)·mask·A·B`` and run the plain
  forward) is kept as the merged parity ORACLE — exercised by tests and by
  the ``factored=False`` flags in ``core/pftt.py``/``core/pfit.py``.
* Adapters are genuine new modules (bottleneck ``up(gelu(down(x)))`` with a
  residual) injected per layer; ``blocks.apply_layer_*`` applies them when
  the key is present.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import trees
from repro.configs.base import ModelConfig

LORA_DEFAULT_TARGETS = ("mixer/wq", "mixer/wv", "mixer/wq_a", "mixer/wq_b",
                        "mixer/wkv_a", "mixer/wkv_b", "mixer/in_proj",
                        "mixer/out_proj")


@dataclasses.dataclass(frozen=True)
class PEFTConfig:
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_targets: Tuple[str, ...] = LORA_DEFAULT_TARGETS
    lora_layers: int = 0          # 0 → all repeats; n → only last n repeats
    adapter_dim: int = 64
    enable_lora: bool = True
    enable_adapters: bool = True


def _is_target(path: str, targets) -> bool:
    return any(path.endswith(t) for t in targets)


# ---------------------------------------------------------------------------
# LoRA
# ---------------------------------------------------------------------------


def init_lora(key, params, peft: PEFTConfig) -> Dict:
    """Mirror of ``params`` with {'a','b','mask'} at each targeted leaf and
    None elsewhere (mergeable structure)."""
    flat = trees.flatten(params)
    seed = [0]

    def make(path, w):
        if not _is_target(path, peft.lora_targets) or w.ndim < 2:
            return None
        k = jax.random.fold_in(key, seed[0]); seed[0] += 1
        *lead, din, dout = w.shape
        r = peft.lora_rank
        a = (jax.random.normal(k, (*lead, din, r)) * din ** -0.5).astype(w.dtype)
        b = jnp.zeros((*lead, r, dout), w.dtype)
        if lead:
            # always (repeats, 1, 1) so the factors AND their enable mask can
            # ride the layer scan together (scalar masks are not scannable)
            mask = (jnp.arange(lead[0]) >= lead[0] - peft.lora_layers
                    if peft.lora_layers else jnp.ones((lead[0],), bool))
            mask = mask.astype(w.dtype).reshape(lead[0], *([1] * 2))
        else:
            mask = jnp.ones((), w.dtype)
        return {"a": a, "b": b, "mask": mask}

    return trees.map_with_path(make, params)


# Trace-time dense-merge accounting: every merge of a present factor leaf
# bumps this counter, so tests and the arch-matrix launcher can assert the
# factored hot path never fell back to materializing ``W + s·A·B`` (compile
# caching means later identical rounds don't re-trace — a zero delta over a
# run proves the fused program contains no merged weights).
_DENSE_MERGE_COUNT = [0]


def dense_merge_count() -> int:
    """Number of factor-leaf dense merges traced so far (process-global)."""
    return _DENSE_MERGE_COUNT[0]


def merge_factors(params, lora, scale: float):
    """Dense-merge ``W + scale·mask·(A·B)`` over any (sub)tree pair.  The
    merged parity oracle — and the per-layer fallback for the one remaining
    module whose internals don't accept factors (the MoE expert FFN)."""
    if lora is None:
        return params

    def combine(w, l):
        if l is None:
            return w
        _DENSE_MERGE_COUNT[0] += 1
        delta = jnp.einsum("...dr,...rf->...df", l["a"], l["b"])
        return w + scale * jax.lax.stop_gradient(l["mask"]) * delta

    return jax.tree_util.tree_map(combine, params, lora,
                                  is_leaf=is_lora_leaf)


def apply_lora(params, lora, peft: PEFTConfig):
    """Materialize W + (α/r)·mask·(A·B) for targeted leaves (merged oracle;
    the hot path threads factors via ``lora_proj`` instead)."""
    if lora is None:
        return params
    return merge_factors(params, lora, peft.lora_alpha / peft.lora_rank)


def merge_lora(params, lora, peft: PEFTConfig):
    """Permanent merge (legacy serving path; factored serving threads the
    lora tree instead — see ``lora_proj``)."""
    return apply_lora(params, lora, peft)


# ---------------------------------------------------------------------------
# Factored (unmerged) execution — the hot-path contract
# ---------------------------------------------------------------------------


def lora_scale(peft: PEFTConfig) -> float:
    """The α/r multiplier of the low-rank path."""
    return peft.lora_alpha / peft.lora_rank


def is_lora_leaf(x) -> bool:
    """is_leaf predicate for {'a','b','mask'} factor dicts (or None)."""
    return x is None or (isinstance(x, dict) and "a" in x)


def has_factors(lf) -> bool:
    """True if a factor (sub)tree carries any actual {'a','b'} leaf —
    distinguishes a real side channel from the all-None mirror
    ``init_lora`` leaves on untargeted weights."""
    if lf is None:
        return False
    return any(isinstance(l, dict) and l.get("a") is not None
               for l in jax.tree_util.tree_leaves(lf, is_leaf=is_lora_leaf))


def effective_weight(w, lf, scale: float):
    """Merge ONE leaf's factors into its base weight: ``W + scale·(A·(mask·
    B))``.  Reserved for contractions that consume the weight itself rather
    than projecting activations through it (absorbed-MLA decode contracts
    q/ctx against ``wkv_b`` directly) — there the merged matrix lives in the
    LATENT space (kv_lora_rank × heads·dims, the same order as the factor's
    own B), never a d_model² delta, so it does not count as a dense-merge
    fallback."""
    if lf is None or lf.get("a") is None:
        return w
    b = lf["b"] * jax.lax.stop_gradient(lf["mask"]).astype(lf["b"].dtype)
    return w + scale * (lf["a"] @ b)


def lora_proj(x, w, lf, *, scale: float, backend: str = "jnp"):
    """Factored LoRA projection ``y = x@W + scale·((x@A)@(mask·B))``.

    ``lf`` is the {'a','b','mask'} factor dict mirroring ``w`` (or None →
    plain ``x@w``).  The dense (din, dout) delta is never materialized, so
    under a client-vmap only the rank-r factors carry the client axis while
    ``w`` stays broadcast.  ``backend="pallas"`` lowers the whole projection
    to the fused ``repro.kernels.lora_fused`` kernel (serving path; 2-D
    unstacked weights only).
    """
    if lf is None or lf.get("a") is None:
        return x @ w
    a, b = lf["a"], lf["b"]
    mask = jax.lax.stop_gradient(lf["mask"])
    # fold the per-layer enable mask into B: mask is () or (1, 1) once the
    # layer scan has sliced the (repeats, 1, 1) leaf, broadcasting over
    # (r, dout) — identical math to masking the dense delta
    b = b * mask.astype(b.dtype)
    if backend == "pallas" and w.ndim == 2 and x.ndim >= 2:
        from repro.kernels.lora_fused.ops import lora_matmul
        return lora_matmul(x, w, a, b, scale=scale)
    return x @ w + scale * ((x @ a) @ b)


@dataclasses.dataclass(frozen=True)
class LoraProj:
    """A projection bundling a frozen base weight with optional rank-r
    factors; calling it runs ``lora_proj``.  ``blocks._proj`` builds one
    per targeted weight so the factored path reads like the dense path."""
    w: object
    lf: Optional[dict] = None
    scale: float = 1.0
    backend: str = "jnp"

    def __call__(self, x):
        return lora_proj(x, self.w, self.lf, scale=self.scale,
                         backend=self.backend)


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------


def adapter_fwd(x, ap):
    """Bottleneck adapter with residual: x + up(gelu(down(x))).
    Called inside the layer scan, so weights are already unstacked 2-D."""
    return x + jax.nn.gelu(x @ ap["wd"]) @ ap["wu"]


def init_adapters(key, params, cfg: ModelConfig, peft: PEFTConfig):
    """Insert an ``adapter`` dict into every stacked layer of every stage.
    Returns a *new params tree* (base params unchanged, adapters added)."""
    new_stages = []
    for si, stage_params in enumerate(params["stages"]):
        stage = cfg.stages[si]
        new_layers = []
        for pi, lp in enumerate(stage_params["layers"]):
            k = jax.random.fold_in(key, si * 64 + pi)
            r = stage.repeats
            a = peft.adapter_dim
            wd = (jax.random.normal(k, (r, cfg.d_model, a))
                  * cfg.d_model ** -0.5).astype(params["embed"].dtype)
            wu = jnp.zeros((r, a, cfg.d_model), params["embed"].dtype)
            new_layers.append(dict(lp, adapter={"wd": wd, "wu": wu}))
        new_stages.append(dict(stage_params, layers=new_layers))
    return dict(params, stages=new_stages)


def strip_adapters(params):
    new_stages = []
    for sp in params["stages"]:
        new_layers = [{k: v for k, v in lp.items() if k != "adapter"}
                      for lp in sp["layers"]]
        new_stages.append(dict(sp, layers=new_layers))
    return dict(params, stages=new_stages)


# ---------------------------------------------------------------------------
# Trainable/frozen splits & path predicates (used by FL aggregation too)
# ---------------------------------------------------------------------------


def is_adapter_path(path: str) -> bool:
    return "/adapter/" in path


def is_lora_path(path: str) -> bool:  # within a lora tree everything is lora
    return True


def last_k_layers_mask(params, cfg: ModelConfig, k: int):
    """Gradient mask: 1.0 on the last-k repeats of the LAST decoder stage
    (+ the final norm / heads), 0.0 elsewhere — PFIT's 'train only the last
    two layers'."""
    decoder_stages = [si for si, s in enumerate(cfg.stages)
                      if s.stream == "decoder"]
    # encoder-only models (roberta): unfreeze the last encoder layers instead
    last_si = max(decoder_stages) if decoder_stages else len(cfg.stages) - 1
    r = cfg.stages[last_si].repeats
    lo = max(0, r - k)

    def mk(path, v):
        if path.startswith(f"stages/{last_si}/layers/"):
            lm = (jnp.arange(r) >= lo).astype(jnp.float32)
            return lm.reshape((r,) + (1,) * (v.ndim - 1))
        if path.startswith(("final_norm", "cls_head", "value_head",
                            "reward_head")):
            return jnp.ones((), jnp.float32)
        return jnp.zeros((), jnp.float32)

    return trees.map_with_path(mk, params)


def head_sparsity_mask(params, cfg: ModelConfig, sparsity: float, seed: int):
    """The paper's sparse-attention *communication* mask: zero out a
    ``sparsity`` fraction of attention heads' q/o parameters (head-structured)
    so they are neither trained nor uploaded.  Deterministic per seed
    (client)."""
    h, hd = cfg.n_heads, cfg.hd
    if h == 0:
        return trees.map_with_path(lambda p, v: jnp.ones((), jnp.float32), params)
    n_keep = max(1, int(round(h * (1.0 - sparsity))))
    key = jax.random.PRNGKey(seed)
    keep = jnp.zeros((h,)).at[
        jax.random.permutation(key, h)[:n_keep]].set(1.0)
    per_dim = jnp.repeat(keep, hd)  # (h*hd,)

    def mk(path, v):
        if re.search(r"mixer/w[qkv]$", path) and v.shape[-1] == h * hd:
            # wq always; wk/wv only when MHA (kv heads == q heads) so the
            # head-structured mask stays well defined under GQA
            return per_dim.reshape((1,) * (v.ndim - 1) + (h * hd,))
        if re.search(r"mixer/wo$", path) and v.shape[-2] == h * hd:
            return per_dim.reshape((1,) * (v.ndim - 2) + (h * hd, 1))
        return jnp.ones((), jnp.float32)

    return trees.map_with_path(mk, params)


def apply_grad_mask(grads, *masks):
    out = grads
    for m in masks:
        out = jax.tree_util.tree_map(lambda g, mm: g * mm.astype(g.dtype),
                                     out, m)
    return out
