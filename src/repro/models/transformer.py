"""Model: scan-based stack runner over stage patterns.

Supports decoder-only (dense/MoE/SSM/hybrid), encoder-only (roberta),
encoder-decoder (whisper), and VLM (prefix patch embeddings) families with
three entry points used by the launchers:

* ``loss``        — training objective (chunked cross-entropy / classifier)
* ``prefill``     — full-prompt forward that builds a decode cache
* ``decode_step`` — one token against the cache (``serve_step``)

Layers are grouped into stages of repeating patterns; parameters of each
pattern position are stacked along a leading repeat axis and the stack is
``lax.scan``ned (small HLO even for 95-layer models), with optional
``jax.checkpoint`` (remat) around the scan body for training.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, Stage
from repro.models.blocks import (LayerCtx, apply_layer_decode, apply_layer_seq,
                                 init_layer, layer_cache_shape)
from repro.models.norms import apply_norm
from repro.sharding import MeshCtx

AUX_WEIGHT = 0.01


def _init_norm(cfg, dim, dtype):
    p = {"scale": jnp.zeros((dim,), dtype)}
    if cfg.norm == "ln":
        p["scale"] = jnp.ones((dim,), dtype)
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


class Model:
    def __init__(self, cfg: ModelConfig, meshctx: Optional[MeshCtx] = None,
                 dtype=jnp.float32, impl: str = "auto", remat: bool = False,
                 seq_shard_boundary: bool = True, opts: Optional[dict] = None):
        self.cfg = cfg
        self.meshctx = meshctx
        self.dtype = dtype
        self.impl = impl
        self.remat = remat
        self.seq_shard_boundary = seq_shard_boundary
        self.opts = opts or {}

    # ------------------------------------------------------------------ init
    def init(self, key, max_seq: int = 0) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = self.dtype
        keys = jax.random.split(key, 8 + len(cfg.stages))
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dtype),
            "final_norm": _init_norm(cfg, cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(
                keys[1], (cfg.d_model, cfg.vocab_size)) * 0.02).astype(dtype)
        if cfg.pos == "learned":
            n_pos = max(cfg.max_position, max_seq, 1024)
            params["pos_embed"] = (jax.random.normal(
                keys[2], (n_pos, cfg.d_model)) * 0.02).astype(dtype)
        if cfg.n_prefix_tokens:
            params["projector"] = (jax.random.normal(
                keys[3], (cfg.prefix_dim, cfg.d_model))
                * cfg.prefix_dim ** -0.5).astype(dtype)
        if cfg.encoder_seq:
            params["enc_pos"] = (jax.random.normal(
                keys[4], (cfg.encoder_seq, cfg.d_model)) * 0.02).astype(dtype)
            params["enc_norm"] = _init_norm(cfg, cfg.d_model, dtype)
        if cfg.n_classes:
            params["cls_head"] = (jax.random.normal(
                keys[5], (cfg.d_model, cfg.n_classes)) * 0.02).astype(dtype)
        stages = []
        for si, stage in enumerate(cfg.stages):
            skey = keys[8 + si]
            layers = []
            for pi, kind in enumerate(stage.pattern):
                pkeys = jax.random.split(
                    jax.random.fold_in(skey, pi), stage.repeats)
                layers.append(jax.vmap(
                    lambda k, kd=kind: init_layer(k, cfg, kd, dtype))(pkeys))
            stages.append({"layers": layers})
        params["stages"] = stages
        return params

    # -------------------------------------------------------------- plumbing
    def _constrain(self, x, seq_shard: bool):
        mc = self.meshctx
        if mc is None or mc.mesh.size <= 1:
            return x
        seq_axis = mc.model_axis if (seq_shard and self.seq_shard_boundary) else None
        spec = mc.spec(x.shape, [mc.batch_axes, seq_axis, None])
        return jax.lax.with_sharding_constraint(x, mc.sharding(spec))

    def _run_stage_seq(self, x, sp, stage: Stage, ctx: LayerCtx,
                       collect_cache: bool, lsp=None):
        """``lsp`` is the stage's LoRA factor subtree (mirrors ``sp``): its
        rank-r leaves are stacked on the same leading repeat axis as the
        params and ride the layer scan as a second xs tree."""
        def body(carry, xs):
            layer_params, layer_lora = xs
            h = carry
            caches = []
            aux = jnp.zeros((), jnp.float32)
            for pi, kind in enumerate(stage.pattern):
                h, c, a = apply_layer_seq(h, layer_params[pi], kind, ctx,
                                          lora=layer_lora[pi])
                caches.append(c)
                aux = aux + a
            h = self._constrain(h, seq_shard=True)
            return h, (caches if collect_cache else 0, aux)

        if self.remat and ctx.mode == "train":
            body = jax.checkpoint(body)
        lora_layers = (tuple(lsp["layers"]) if lsp is not None
                       else tuple(None for _ in sp["layers"]))
        x, (caches, auxs) = jax.lax.scan(body, x,
                                         (tuple(sp["layers"]), lora_layers))
        return x, caches, auxs.sum()

    def _embed_tokens(self, params, tokens, positions):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, self.dtype)
        if cfg.pos == "learned":
            pos_table = params["pos_embed"]
            x = x + pos_table[positions].astype(self.dtype)
        return x

    @staticmethod
    def _lora_stage(lora, si):
        """The per-stage slice of a LoRA side-channel tree (None-safe)."""
        return None if lora is None else lora["stages"][si]

    @staticmethod
    def _check_lora(lora):
        """The factored side channel only reaches layer-stack projections;
        factors mirroring any other leaf (cls_head, lm_head, embed, …)
        would be SILENTLY ignored — fail loudly at trace time instead
        (the merged oracle ``peft.apply_lora`` does support them)."""
        if lora is None:
            return
        from repro import trees
        stray = [p for p in trees.flatten(lora) if not p.startswith("stages/")]
        if stray:
            raise ValueError(
                "factored LoRA execution only supports factors on stage "
                f"layer weights; found factors at {sorted(set(stray))} — "
                "merge these with peft.apply_lora instead")

    def _encode(self, params, frames, ctx_kwargs, lora=None):
        """Whisper encoder: frames are post-conv embeddings (B, S_enc, d)."""
        cfg = self.cfg
        x = frames.astype(self.dtype) + params["enc_pos"][None].astype(self.dtype)
        ctx = LayerCtx(cfg=cfg, meshctx=self.meshctx,
                       positions=jnp.arange(frames.shape[1]),
                       causal=False, opts=self.opts, **ctx_kwargs)
        for si, stage in enumerate(cfg.stages):
            if stage.stream != "encoder":
                continue
            x, _, _ = self._run_stage_seq(x, params["stages"][si], stage, ctx,
                                          collect_cache=False,
                                          lsp=self._lora_stage(lora, si))
        return apply_norm(x, params["enc_norm"], cfg.norm, cfg.norm_eps)

    # -------------------------------------------------------------- forward
    def forward(self, params, tokens, *, frames=None, patches=None,
                impl: Optional[str] = None, mode: str = "train",
                collect_cache: bool = False, lora=None,
                lora_scale: float = 1.0):
        """Returns (hidden, aux[, caches]).  tokens: (B, S_text).

        ``lora`` is an optional factored-LoRA side channel (``peft.init_lora``
        structure, mirroring ``params``): targeted projections run
        ``y = x@W + lora_scale·(x@A)@B`` without merging, so the base stays
        unbatched under an outer client-vmap."""
        cfg = self.cfg
        impl = impl or self.impl
        self._check_lora(lora)
        memory = None
        if cfg.is_encoder_decoder:
            memory = self._encode(params, frames,
                                  dict(impl=impl, mode=mode,
                                       lora_scale=lora_scale), lora=lora)
        if cfg.is_encoder_only:
            positions = jnp.arange(tokens.shape[1])
            x = self._embed_tokens(params, tokens, positions)
            ctx = LayerCtx(cfg=cfg, meshctx=self.meshctx, positions=positions,
                           impl=impl, mode=mode, causal=False, opts=self.opts,
                           lora_scale=lora_scale)
            aux_total = jnp.zeros((), jnp.float32)
            for si, stage in enumerate(cfg.stages):
                x, _, aux = self._run_stage_seq(x, params["stages"][si], stage,
                                                ctx, collect_cache=False,
                                                lsp=self._lora_stage(lora, si))
                aux_total += aux
            x = apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
            return (x, aux_total, None) if collect_cache else (x, aux_total)

        if cfg.n_prefix_tokens:
            prefix = (patches.astype(self.dtype) @ params["projector"])
            positions = jnp.arange(cfg.n_prefix_tokens + tokens.shape[1])
            xt = self._embed_tokens(params, tokens,
                                    positions[cfg.n_prefix_tokens:])
            x = jnp.concatenate([prefix, xt], axis=1)
        else:
            positions = jnp.arange(tokens.shape[1])
            x = self._embed_tokens(params, tokens, positions)

        ctx = LayerCtx(cfg=cfg, meshctx=self.meshctx, positions=positions,
                       impl=impl, memory=memory, mode=mode, opts=self.opts,
                       lora_scale=lora_scale)
        x = self._constrain(x, seq_shard=True)
        aux_total = jnp.zeros((), jnp.float32)
        caches = []
        for si, stage in enumerate(cfg.stages):
            if stage.stream != "decoder":
                caches.append(None)
                continue
            x, c, aux = self._run_stage_seq(x, params["stages"][si], stage,
                                            ctx, collect_cache=collect_cache,
                                            lsp=self._lora_stage(lora, si))
            caches.append(c)
            aux_total += aux
        x = apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        if collect_cache:
            return x, aux_total, caches
        return x, aux_total

    # ----------------------------------------------------------------- loss
    def _lm_head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def lm_loss(self, params, batch, *, impl: Optional[str] = None,
                chunk: int = 512, lora=None, lora_scale: float = 1.0):
        """Chunked cross-entropy: never materializes (B, S, vocab)."""
        cfg = self.cfg
        hidden, aux = self.forward(
            params, batch["tokens"], frames=batch.get("frames"),
            patches=batch.get("patches"), impl=impl, mode="train",
            lora=lora, lora_scale=lora_scale)
        labels, mask = batch["labels"], batch["mask"]
        if cfg.n_prefix_tokens:  # loss only on text positions
            hidden = hidden[:, cfg.n_prefix_tokens:]
        b, s, d = hidden.shape
        head = self._lm_head(params)
        chunk = min(chunk, s)
        if s % chunk:
            chunk = s
        nc = s // chunk
        hc = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
        lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)
        mc = mask.reshape(b, nc, chunk).swapaxes(0, 1)

        def step(carry, xs):
            h, l, m = xs
            logits = (h @ head).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
            return (carry[0] + ((logz - ll) * m).sum(),
                    carry[1] + m.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, lc, mc))
        return tot / jnp.maximum(cnt, 1.0) + AUX_WEIGHT * aux

    def cls_loss(self, params, batch, *, impl: Optional[str] = None,
                 lora=None, lora_scale: float = 1.0):
        """Encoder classifier loss (PFTT / roberta).  batch: tokens, label,
        and optionally ``valid`` — a (B,) sample weight the padded ragged-
        cohort path rides in (``cohort.HostBatchStacker``): the weighted
        mean over real rows equals the plain mean of the unpadded batch, so
        padded rows contribute exactly zero to loss and gradients."""
        hidden, aux = self.forward(params, batch["tokens"], impl=impl,
                                   lora=lora, lora_scale=lora_scale)
        logits = (hidden[:, 0] @ params["cls_head"]).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch["label"][:, None], axis=-1)[:, 0]
        correct = (logits.argmax(-1) == batch["label"]).astype(jnp.float32)
        w = batch.get("valid")
        if w is None:
            return (logz - ll).mean() + AUX_WEIGHT * aux, correct.mean()
        wsum = jnp.maximum(w.sum(), 1.0)
        return (((logz - ll) * w).sum() / wsum + AUX_WEIGHT * aux,
                (correct * w).sum() / wsum)

    def logits(self, params, hidden):
        return (hidden @ self._lm_head(params)).astype(jnp.float32)

    # ---------------------------------------------------------------- cache
    def init_cache(self, batch: int, cache_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        stages = []
        for stage in cfg.stages:
            if stage.stream != "decoder":
                stages.append(None)
                continue
            entries = []
            for kind in stage.pattern:
                shapes = layer_cache_shape(
                    cfg, kind, batch, cache_len, dtype,
                    sparse_kv=bool(self.opts.get("sparse_kv_seq")))
                entries.append({k: jnp.zeros((stage.repeats,) + shp, dt)
                                for k, (shp, dt) in shapes.items()})
            stages.append(entries)
        return {"pos": jnp.zeros((), jnp.int32), "stages": stages}

    def cache_spec(self, batch: int, cache_len: int, dtype=None):
        """ShapeDtypeStruct pytree of the cache (for dry-run lowering)."""
        dtype = dtype or self.dtype
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, cache_len, dtype))

    # -------------------------------------------------------------- prefill
    def prefill(self, params, tokens, cache_len: int, *, frames=None,
                patches=None, impl: Optional[str] = None, lora=None,
                lora_scale: float = 1.0):
        """Run the prompt, return (last_token_logits, cache)."""
        cfg = self.cfg
        hidden, _, caches = self.forward(
            params, tokens, frames=frames, patches=patches, impl=impl,
            mode="prefill", collect_cache=True, lora=lora,
            lora_scale=lora_scale)
        s_prompt = hidden.shape[1]
        stages = []
        for si, stage in enumerate(cfg.stages):
            if stage.stream != "decoder":
                stages.append(None)
                continue
            entries = []
            for pi, kind in enumerate(stage.pattern):
                entry = {}
                raw = caches[si][pi]
                shapes = layer_cache_shape(cfg, kind, tokens.shape[0],
                                           cache_len, self.dtype)
                for name, (shp, dt) in shapes.items():
                    full = jnp.zeros((stage.repeats,) + shp, dt)
                    got = raw[name].astype(dt)
                    if name in ("h", "conv", "xk", "xv"):
                        entry[name] = got
                        continue
                    sc = shp[1]  # cache seq length for this layer kind
                    if got.shape[2] <= sc:
                        entry[name] = jax.lax.dynamic_update_slice_in_dim(
                            full, got, 0, axis=2)
                    else:  # ring (window) cache: keep last sc positions
                        tail = got[:, :, -sc:]
                        slots = jnp.mod(jnp.arange(s_prompt - sc, s_prompt), sc)
                        entry[name] = full.at[:, :, slots].set(tail)
                entries.append(entry)
            stages.append(entries)
        cache = {"pos": jnp.asarray(s_prompt, jnp.int32), "stages": stages}
        last = hidden[:, -1]
        return self.logits(params, last), cache

    # ---------------------------------------------------------------- decode
    def decode_step(self, params, cache, tokens, *, impl: Optional[str] = None,
                    lora=None, lora_scale: float = 1.0):
        """tokens: (B, 1) → (logits (B, vocab), updated cache)."""
        cfg = self.cfg
        impl = impl or self.impl
        self._check_lora(lora)
        pos = cache["pos"]
        x = self._embed_tokens(params, tokens,
                               jnp.full(tokens.shape, pos, jnp.int32))
        ctx = LayerCtx(cfg=cfg, meshctx=self.meshctx, positions=None,
                       impl=impl, mode="decode", pos=pos, opts=self.opts,
                       lora_scale=lora_scale)
        new_stages = []
        for si, stage in enumerate(cfg.stages):
            if stage.stream != "decoder":
                new_stages.append(cache["stages"][si])
                continue

            def body(carry, xs, stage=stage):
                h = carry
                layer_params, cache_slices, layer_lora = xs
                new_slices = []
                for pi, kind in enumerate(stage.pattern):
                    h, nc = apply_layer_decode(h, layer_params[pi], kind,
                                               cache_slices[pi], ctx,
                                               lora=layer_lora[pi])
                    new_slices.append(nc)
                return h, new_slices

            lsp = self._lora_stage(lora, si)
            lora_layers = (tuple(lsp["layers"]) if lsp is not None
                           else tuple(None for _ in stage.pattern))
            x, new_cache = jax.lax.scan(
                body, x, (tuple(params["stages"][si]["layers"]),
                          tuple(cache["stages"][si]), lora_layers))
            new_stages.append(list(new_cache))
        x = apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits = self.logits(params, x[:, 0])
        return logits, {"pos": pos + 1, "stages": new_stages}
