"""Normalization layers (functional)."""
import jax.numpy as jnp


def rmsnorm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x, params, kind: str, eps: float):
    if kind == "rms":
        return rmsnorm(x, params["scale"], eps)
    return layernorm(x, params["scale"], params["bias"], eps)
