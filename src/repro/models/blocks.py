"""Layer-kind dispatch: init / full-sequence forward / single-token decode.

A layer is (mixer, ff) with pre-norm residual structure:

    x = x + mixer(norm1(x))          [dec adds a cross-attention sublayer]
    x = x + ff(norm2(x))             [if ff != none]

All functions are scan-friendly: parameters for a repeated pattern position
are stacked along a leading repeat axis by ``transformer.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerKind, ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import ssm as ssm_mod
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_ffn, moe_ffn_a2a
from repro.models.norms import apply_norm
from repro.models.peft import LoraProj, has_factors, merge_factors
from repro.models.rope import apply_rope
from repro.sharding import MeshCtx


@dataclasses.dataclass
class LayerCtx:
    """Trace-time context threaded through layer application."""
    cfg: ModelConfig
    meshctx: Optional[MeshCtx]
    positions: Any            # (S,) or (B,S) int — absolute positions
    impl: str = "auto"        # auto | dense | chunked | sparse
    memory: Any = None        # encoder output for cross-attention
    q_offset: Any = 0
    mode: str = "train"       # train | prefill | decode
    pos: Any = None           # decode: traced scalar write position
    causal: bool = True
    opts: dict = dataclasses.field(default_factory=dict)  # §Perf knobs
    lora_scale: float = 1.0   # α/r for factored LoRA side-channel trees


def _sub(lora, *keys):
    """Navigate a lora side-channel subtree; None anywhere → None."""
    for k in keys:
        if lora is None:
            return None
        lora = lora.get(k)
    return lora


def _proj(x, w, lf, ctx: LayerCtx):
    """LoRA-aware projection: factored ``LoraProj`` when factors ride
    along, plain matmul otherwise."""
    return LoraProj(w, lf, ctx.lora_scale,
                    ctx.opts.get("lora_backend", "jnp"))(x)


def _lkw(ctx: LayerCtx, mf):
    """Factored side-channel kwargs for mla/ssm entry points."""
    return dict(lora=mf, scale=ctx.lora_scale,
                backend=ctx.opts.get("lora_backend", "jnp"))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_norm(cfg: ModelConfig, dim: int, dtype):
    p = {"scale": jnp.zeros((dim,), dtype)}
    if cfg.norm == "ln":
        p["scale"] = jnp.ones((dim,), dtype)
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def _init_attn_proj(key, cfg: ModelConfig, dtype):
    d, h, k_, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, k_ * hd)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, k_ * hd)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5).astype(dtype),
    }


def init_layer(key, cfg: ModelConfig, kind: LayerKind, dtype):
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": _init_norm(cfg, cfg.d_model, dtype)}
    if kind.mixer in ("attn", "local", "enc", "dec"):
        p["mixer"] = _init_attn_proj(ks[0], cfg, dtype)
        if kind.mixer == "dec":
            p["cross"] = _init_attn_proj(ks[3], cfg, dtype)
            p["norm_x"] = _init_norm(cfg, cfg.d_model, dtype)
    elif kind.mixer == "mla":
        p["mixer"] = mla_mod.init_mla(ks[0], cfg.d_model, cfg.n_heads, cfg.mla, dtype)
    elif kind.mixer == "mamba":
        p["mixer"] = ssm_mod.init_mamba(ks[0], cfg.d_model, cfg.ssm, dtype)
    if kind.ff == "mlp":
        p["norm2"] = _init_norm(cfg, cfg.d_model, dtype)
        p["ff"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    elif kind.ff == "moe":
        p["norm2"] = _init_norm(cfg, cfg.d_model, dtype)
        p["ff"] = init_moe(ks[1], cfg.d_model, cfg.moe, cfg.act, dtype)
    return p


# ---------------------------------------------------------------------------
# attention helpers
# ---------------------------------------------------------------------------


def _qkv(xn, mp, cfg: ModelConfig, positions, use_rope: bool,
         lf=None, ctx: Optional[LayerCtx] = None):
    b, s, _ = xn.shape
    h, k_, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if lf is None or ctx is None:
        q = (xn @ mp["wq"]).reshape(b, s, h, hd)
        k = (xn @ mp["wk"]).reshape(b, s, k_, hd)
        v = (xn @ mp["wv"]).reshape(b, s, k_, hd)
    else:
        q = _proj(xn, mp["wq"], _sub(lf, "wq"), ctx).reshape(b, s, h, hd)
        k = _proj(xn, mp["wk"], _sub(lf, "wk"), ctx).reshape(b, s, k_, hd)
        v = _proj(xn, mp["wv"], _sub(lf, "wv"), ctx).reshape(b, s, k_, hd)
    if use_rope and cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_core_seq(q, k, v, kind: LayerKind, cfg: ModelConfig, ctx: LayerCtx):
    s = q.shape[1]
    causal = ctx.causal and kind.mixer != "enc"
    window = cfg.window if kind.mixer == "local" else 0
    if kind.mixer in ("attn", "dec") and ctx.impl == "sparse" and cfg.sparse_attn:
        return attn.block_sparse_attention(q, k, v, cfg.sparse_attn,
                                           q_offset=ctx.q_offset)
    if ctx.impl == "dense" or s <= 2048:
        return attn.dense_attention(q, k, v, causal=causal, window=window,
                                    q_offset=ctx.q_offset)
    if causal and ctx.opts.get("causal_skip"):
        return attn.chunked_attention_pairs(q, k, v, causal=True,
                                            window=window,
                                            q_offset=ctx.q_offset)
    return attn.chunked_attention(q, k, v, causal=causal, window=window,
                                  q_offset=ctx.q_offset)


# ---------------------------------------------------------------------------
# full-sequence layer application
# ---------------------------------------------------------------------------


def apply_layer_seq(x, lp, kind: LayerKind, ctx: LayerCtx, lora=None):
    """Returns (x, cache_entry, aux).  cache_entry is the per-layer state to
    seed a decode cache (k/v, compressed kv, or ssm states).  ``lora`` is the
    layer's factor subtree (mirrors ``lp``; None → dense path)."""
    cfg = ctx.cfg
    xn = apply_norm(x, lp["norm1"], cfg.norm, cfg.norm_eps)
    cache_entry = None
    aux = jnp.zeros((), jnp.float32)

    if kind.mixer in ("attn", "local", "enc", "dec"):
        mf = _sub(lora, "mixer")
        q, k, v = _qkv(xn, lp["mixer"], cfg, ctx.positions, use_rope=True,
                       lf=mf, ctx=ctx)
        y = _attn_core_seq(q, k, v, kind, cfg, ctx)
        b, s = y.shape[:2]
        x = x + _proj(y.reshape(b, s, -1), lp["mixer"]["wo"],
                      _sub(mf, "wo"), ctx)
        if kind.mixer != "enc":
            cache_entry = {"k": k, "v": v}
        if kind.mixer == "dec":
            cf = _sub(lora, "cross")
            xn2 = apply_norm(x, lp["norm_x"], cfg.norm, cfg.norm_eps)
            qx = _proj(xn2, lp["cross"]["wq"], _sub(cf, "wq"),
                       ctx).reshape(b, s, cfg.n_heads, cfg.hd)
            mem = ctx.memory
            kx = _proj(mem, lp["cross"]["wk"], _sub(cf, "wk"), ctx).reshape(
                mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.hd)
            vx = _proj(mem, lp["cross"]["wv"], _sub(cf, "wv"), ctx).reshape(
                mem.shape[0], mem.shape[1], cfg.n_kv_heads, cfg.hd)
            yx = attn.dense_attention(qx, kx, vx, causal=False)
            x = x + _proj(yx.reshape(b, s, -1), lp["cross"]["wo"],
                          _sub(cf, "wo"), ctx)
            cache_entry["xk"] = kx
            cache_entry["xv"] = vx
    elif kind.mixer == "mla":
        # factored path: mla takes the lora side channel directly — the
        # frozen base is never re-materialized under the client vmap
        mf = _sub(lora, "mixer")
        impl = ctx.impl if ctx.impl != "auto" else (
            "dense" if x.shape[1] <= 2048 else "chunked")
        y, (ckv, kpe) = mla_mod.mla_seq(
            xn, lp["mixer"], cfg.mla, cfg.n_heads, ctx.positions,
            cfg.rope_theta, cfg.norm_eps, causal=ctx.causal, impl=impl,
            sparse_cfg=cfg.sparse_attn, q_offset=ctx.q_offset,
            causal_skip=ctx.opts.get("causal_skip", False),
            **_lkw(ctx, mf))
        x = x + y
        cache_entry = {"ckv": ckv, "kpe": kpe}
    elif kind.mixer == "mamba":
        mf = _sub(lora, "mixer")
        if (ctx.opts.get("mamba_sp") and ctx.mode == "train"
                and ctx.meshctx is not None and not has_factors(mf)):
            # sequence-parallel SSD: activations stay seq-sharded (§Perf B2);
            # its shard_map replicates raw weights, so factored layers route
            # through the plain factored mamba_seq below instead
            x = x + ssm_mod.mamba_seq_sp(xn, lp["mixer"], cfg.ssm,
                                         cfg.d_model, cfg.norm_eps,
                                         ctx.meshctx)
        else:
            y, (h_final, conv_state) = ssm_mod.mamba_seq(
                xn, lp["mixer"], cfg.ssm, cfg.d_model, cfg.norm_eps,
                **_lkw(ctx, mf))
            x = x + y
            cache_entry = {"h": h_final, "conv": conv_state}

    if kind.ff != "none":
        xn2 = apply_norm(x, lp["norm2"], cfg.norm, cfg.norm_eps)
        if kind.ff == "mlp":
            x = x + mlp(xn2, lp["ff"], cfg.act, lora=_sub(lora, "ff"),
                        scale=ctx.lora_scale,
                        backend=ctx.opts.get("lora_backend", "jnp"))
        elif ctx.opts.get("moe_a2a"):
            fp = merge_factors(lp["ff"], _sub(lora, "ff"), ctx.lora_scale)
            y, aux = moe_ffn_a2a(xn2, fp, cfg.moe, ctx.meshctx, cfg.act)
            x = x + y
        else:
            fp = merge_factors(lp["ff"], _sub(lora, "ff"), ctx.lora_scale)
            y, aux = moe_ffn(xn2, fp, cfg.moe, ctx.meshctx, cfg.act)
            x = x + y
    if "adapter" in lp:  # PFTT universal adapter (bottleneck + residual)
        from repro.models.peft import adapter_fwd
        x = adapter_fwd(x, lp["adapter"])
    return x, cache_entry, aux


# ---------------------------------------------------------------------------
# decode layer application
# ---------------------------------------------------------------------------


def _cache_write(cache, new, slot):
    """Write one token's k/v (B,1,K,hd) at ``slot`` (traced scalar)."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype),
                                               slot, axis=1)


def apply_layer_decode(x, lp, kind: LayerKind, cache, ctx: LayerCtx,
                       lora=None):
    """x: (B,1,d).  Returns (x, new_cache).  ``lora`` as in
    ``apply_layer_seq`` (factored serving: base stays unmerged)."""
    cfg = ctx.cfg
    pos = ctx.pos
    xn = apply_norm(x, lp["norm1"], cfg.norm, cfg.norm_eps)
    new_cache = cache

    def _ff(x, lq=lora):
        xn2 = apply_norm(x, lp["norm2"], cfg.norm, cfg.norm_eps)
        if kind.ff == "mlp":
            return x + mlp(xn2, lp["ff"], cfg.act, lora=_sub(lq, "ff"),
                           scale=ctx.lora_scale,
                           backend=ctx.opts.get("lora_backend", "jnp"))
        fp = merge_factors(lp["ff"], _sub(lq, "ff"), ctx.lora_scale)
        y, _ = moe_ffn(xn2, fp, cfg.moe, ctx.meshctx, cfg.act)
        return x + y

    if kind.mixer in ("attn", "local", "dec"):
        mf = _sub(lora, "mixer")
        positions = jnp.full((x.shape[0], 1), pos)
        q, k, v = _qkv(xn, lp["mixer"], cfg, positions, use_rope=True,
                       lf=mf, ctx=ctx)
        if "k_pers" in cache:  # sparse KV cache (§Perf C)
            new_cache = attn.sparse_kv_write(cache, k, v, pos,
                                             cfg.sparse_attn,
                                             ctx.opts["sparse_kv_seq"])
            y = attn.sparse_kv_decode(q, new_cache, pos, cfg.sparse_attn,
                                      ctx.opts["sparse_kv_seq"])
            x = x + _proj(y.reshape(x.shape[0], 1, -1), lp["mixer"]["wo"],
                          _sub(mf, "wo"), ctx)
            if kind.ff != "none":
                x = _ff(x)
            if "adapter" in lp:
                from repro.models.peft import adapter_fwd
                x = adapter_fwd(x, lp["adapter"])
            return x, new_cache
        sc = cache["k"].shape[1]
        ring = kind.mixer == "local" and cfg.window > 0 and sc <= cfg.window
        slot = jnp.mod(pos, sc) if ring else jnp.minimum(pos, sc - 1)
        kc = _cache_write(cache["k"], k, slot)
        vc = _cache_write(cache["v"], v, slot)
        sparse = cfg.sparse_attn if (ctx.impl == "sparse" and kind.mixer != "local") else None
        if sparse is not None and not ring and ctx.opts.get("sparse_gather_decode"):
            y = attn.sparse_gather_decode(q, kc, vc, pos, sparse)
        else:
            y = attn.decode_attention(
                q, kc, vc, pos + 1,
                window=cfg.window if kind.mixer == "local" else 0,
                sparse=sparse, ring=ring)
        x = x + _proj(y.reshape(x.shape[0], 1, -1), lp["mixer"]["wo"],
                      _sub(mf, "wo"), ctx)
        new_cache = dict(cache, k=kc, v=vc)
        if kind.mixer == "dec":
            cf = _sub(lora, "cross")
            xn2 = apply_norm(x, lp["norm_x"], cfg.norm, cfg.norm_eps)
            qx = _proj(xn2, lp["cross"]["wq"], _sub(cf, "wq"), ctx).reshape(
                x.shape[0], 1, cfg.n_heads, cfg.hd)
            yx = attn.decode_attention(qx, cache["xk"], cache["xv"],
                                       cache["xk"].shape[1])
            x = x + _proj(yx.reshape(x.shape[0], 1, -1), lp["cross"]["wo"],
                          _sub(cf, "wo"), ctx)
    elif kind.mixer == "mla":
        mf = _sub(lora, "mixer")
        c_kv, k_pe = mla_mod._compress_kv(
            xn, lp["mixer"], cfg.mla, jnp.full((x.shape[0], 1), pos),
            cfg.rope_theta, cfg.norm_eps, **_lkw(ctx, mf))
        ckv = _cache_write(cache["ckv"], c_kv, pos)
        kpe = _cache_write(cache["kpe"], k_pe, pos)
        sparse = cfg.sparse_attn if ctx.impl == "sparse" else None
        y = mla_mod.mla_decode(xn, lp["mixer"], cfg.mla, cfg.n_heads, pos,
                               cfg.rope_theta, cfg.norm_eps, ckv, kpe,
                               sparse_cfg=sparse, **_lkw(ctx, mf))
        x = x + y
        new_cache = dict(cache, ckv=ckv, kpe=kpe)
    elif kind.mixer == "mamba":
        mf = _sub(lora, "mixer")
        y, (h, conv) = ssm_mod.mamba_decode(
            xn, lp["mixer"], cfg.ssm, cfg.d_model, cfg.norm_eps,
            cache["h"], cache["conv"], **_lkw(ctx, mf))
        x = x + y
        new_cache = dict(cache, h=h, conv=conv)

    if kind.ff != "none":
        x = _ff(x)
    if "adapter" in lp:
        from repro.models.peft import adapter_fwd
        x = adapter_fwd(x, lp["adapter"])
    return x, new_cache


# ---------------------------------------------------------------------------
# cache shapes / init
# ---------------------------------------------------------------------------


def layer_cache_shape(cfg: ModelConfig, kind: LayerKind, batch: int,
                      cache_len: int, dtype, sparse_kv: bool = False):
    """Abstract cache entry for one layer (no leading repeat axis)."""
    if sparse_kv and kind.mixer == "attn" and cfg.sparse_attn is not None:
        from repro.models.attention import sparse_kv_layout
        _, _, ring_slots, n_pers = sparse_kv_layout(cache_len, cfg.sparse_attn)
        kk, hd = cfg.n_kv_heads, cfg.hd
        return {"k_pers": ((batch, n_pers, kk, hd), dtype),
                "v_pers": ((batch, n_pers, kk, hd), dtype),
                "k_ring": ((batch, ring_slots, kk, hd), dtype),
                "v_ring": ((batch, ring_slots, kk, hd), dtype)}
    if kind.mixer in ("attn", "dec"):
        c = {"k": ((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype),
             "v": ((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)}
        if kind.mixer == "dec":
            c["xk"] = ((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dtype)
            c["xv"] = ((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dtype)
        return c
    if kind.mixer == "local":
        sc = min(cache_len, cfg.window) if cfg.window else cache_len
        return {"k": ((batch, sc, cfg.n_kv_heads, cfg.hd), dtype),
                "v": ((batch, sc, cfg.n_kv_heads, cfg.hd), dtype)}
    if kind.mixer == "mla":
        m = cfg.mla
        return {"ckv": ((batch, cache_len, m.kv_lora_rank), dtype),
                "kpe": ((batch, cache_len, m.rope_head_dim), dtype)}
    if kind.mixer == "mamba":
        s = cfg.ssm
        d_in = cfg.d_inner
        h = cfg.ssm_heads
        conv_dim = d_in + 2 * s.n_groups * s.state
        return {"h": ((batch, h, s.headdim, s.state), jnp.float32),
                "conv": ((batch, s.conv_width - 1, conv_dim), dtype)}
    return {}


# ---------------------------------------------------------------------------
# analytic parameter counts (accounting / roofline)
# ---------------------------------------------------------------------------


def layer_param_count(cfg: ModelConfig, kind: LayerKind,
                      active_only: bool = False) -> int:
    d = cfg.d_model
    n = d  # norm1
    if kind.mixer in ("attn", "local", "enc", "dec"):
        n += d * cfg.n_heads * cfg.hd * 2 + d * cfg.n_kv_heads * cfg.hd * 2
        if kind.mixer == "dec":
            n += d * cfg.n_heads * cfg.hd * 2 + d * cfg.n_kv_heads * cfg.hd * 2 + d
    elif kind.mixer == "mla":
        m = cfg.mla
        qk = m.nope_head_dim + m.rope_head_dim
        n += (d * m.q_lora_rank + m.q_lora_rank
              + m.q_lora_rank * cfg.n_heads * qk
              + d * (m.kv_lora_rank + m.rope_head_dim) + m.kv_lora_rank
              + m.kv_lora_rank * cfg.n_heads * (m.nope_head_dim + m.v_head_dim)
              + cfg.n_heads * m.v_head_dim * d)
    elif kind.mixer == "mamba":
        s = cfg.ssm
        d_in = cfg.d_inner
        h = cfg.ssm_heads
        conv_dim = d_in + 2 * s.n_groups * s.state
        proj_out = 2 * d_in + 2 * s.n_groups * s.state + h
        n += (d * proj_out + s.conv_width * conv_dim + conv_dim
              + 3 * h + d_in + d_in * d)
    if kind.ff == "mlp":
        mult = 3 if cfg.act in ("swiglu", "geglu") else 2
        n += d + mult * d * cfg.d_ff
    elif kind.ff == "moe":
        m = cfg.moe
        mult = 3 if cfg.act in ("swiglu", "geglu") else 2
        e = m.top_k if active_only else m.n_experts
        n += d + d * m.n_experts + e * mult * d * m.d_ff
        if m.n_shared_experts:
            n += mult * d * (m.n_shared_experts * m.d_ff)
    return n
