"""Mixture-of-Experts feed-forward with expert parallelism.

Distribution scheme (DESIGN.md §5): *replicated-activation expert
parallelism* under ``shard_map`` — layer-boundary activations are already
replicated along the ``model`` axis (tensor-parallel layout), experts are
sharded along ``model``.  Each device routes its local (data-shard) tokens,
gathers the capacity-C token set for **its** experts, runs a batched GEMM over
(E_local, C, d), scatters back, and a single ``psum`` over the model axis
combines expert contributions.  No dispatch all-to-all is required at this
topology; the psum is the same collective a tensor-parallel dense FF needs.

Routing is token-choice top-k with capacity dropping (sort-based dispatch
table, gather/scatter with ``mode='drop'``).  For tiny token counts (decode)
capacity is set to T·k → dropless.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models.mlp import act_fn
from repro.sharding import MeshCtx, shard_map


def _capacity(n_tokens: int, cfg: MoEConfig) -> int:
    tk = n_tokens * cfg.top_k
    if tk <= 4096:
        return tk  # dropless for small batches (decode / smoke)
    c = int(tk * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def _local_moe(x, router, wg, wu, wd, *, cfg: MoEConfig, act: str,
               e_loc: int, model_axis: str, shard_experts: bool,
               batch_axes: Tuple[str, ...], psum_axes: Tuple[str, ...] = ()):
    """Per-device body.  x: (B_loc, S, d) local tokens (replicated along the
    model axis); wg/wu/wd: (E_loc, d, f)/(E_loc, f, d) local expert slabs."""
    b, s, d = x.shape
    t = b * s
    e = cfg.n_experts
    k = cfg.top_k
    c = _capacity(t, cfg)
    xt = x.reshape(t, d)

    gates = jax.nn.softmax((xt.astype(jnp.float32) @ router.astype(jnp.float32)))
    w, idx = jax.lax.top_k(gates, k)                      # (t, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                              # (t*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos = jnp.arange(t * k) - starts[sorted_e]
    e0 = (jax.lax.axis_index(model_axis) * e_loc) if shard_experts else 0
    local_e = sorted_e - e0
    ok = (pos < c) & (local_e >= 0) & (local_e < e_loc)
    le = jnp.where(ok, local_e, e_loc)                    # OOB → dropped
    pc = jnp.where(ok, pos, c)
    tok = order // k

    table = jnp.full((e_loc, c), t, jnp.int32).at[le, pc].set(tok, mode="drop")
    wtab = jnp.zeros((e_loc, c), jnp.float32).at[le, pc].set(
        w.reshape(-1)[order], mode="drop")

    xp = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
    xe = xp[table]                                        # (E_loc, C, d)
    if act in ("swiglu", "geglu"):
        h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
            jnp.einsum("ecd,edf->ecf", xe, wu)
    else:
        h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, wu))
    ye = jnp.einsum("ecf,efd->ecd", h, wd)
    ye = (ye.astype(jnp.float32) * wtab[..., None]).astype(x.dtype)

    y = jnp.zeros((t + 1, d), x.dtype).at[table].add(ye)[:t]
    if psum_axes:
        y = jax.lax.psum(y, psum_axes)

    # Switch-style load-balance auxiliary loss (replicated along model axis).
    frac_routed = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    frac_prob = gates.mean(0)
    aux = e * jnp.sum(frac_routed * frac_prob)
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    return y.reshape(b, s, d), aux


def moe_ffn(x, params, cfg: MoEConfig, meshctx: MeshCtx, act: str):
    """x: (B, S, d) global.  Returns (y, aux_loss)."""
    msize = meshctx.model_size
    shard_experts = msize > 1 and cfg.n_experts % msize == 0
    e_loc = cfg.n_experts // msize if shard_experts else cfg.n_experts

    e_ax = meshctx.model_axis if shard_experts else None
    # batch dim shards over the data axes only when divisible (long_500k has
    # global_batch=1 → tokens replicated, experts still sharded).  At decode
    # (S == 1) tokens are ALWAYS replicated: gathering B·d token bytes (~MBs)
    # is far cheaper than gathering FSDP expert slabs every layer — the
    # 2D-sharded expert path below then applies.
    batch_ax = (None if x.shape[1] == 1
                else meshctx.dim_axis(x.shape[0], meshctx.batch_axes))
    # When tokens are replicated over the data axes (decode, B < data size),
    # 2D-shard the experts: E over model AND f over data — avoids gathering
    # the expert slabs (FSDP layout) every layer for one token; the partial
    # f-contributions fold into the same psum.
    f_ax = (meshctx.dim_axis(cfg.d_ff, meshctx.batch_axes)
            if batch_ax is None else None)
    gu_spec = P(e_ax, None, f_ax)
    d_spec = P(e_ax, f_ax, None)
    psum_axes = (meshctx.model_axis,) if shard_experts else ()
    if f_ax is not None:
        psum_axes = psum_axes + tuple(meshctx.batch_axes)
    bspec = P(batch_ax, None, None)
    aux_axes = meshctx.batch_axes if batch_ax is not None else ()
    body = functools.partial(
        _local_moe, cfg=cfg, act=act, e_loc=e_loc,
        model_axis=meshctx.model_axis, shard_experts=shard_experts,
        batch_axes=aux_axes, psum_axes=psum_axes)

    y, aux = shard_map(
        body, mesh=meshctx.mesh,
        in_specs=(bspec, P(None, None), gu_spec, gu_spec, d_spec),
        out_specs=(bspec, P()),
        check_vma=False,
    )(x, params["router"], params["wg"], params["wu"], params["wd"])

    # shared (always-on) experts — a plain dense FF of width n_shared·f
    if cfg.n_shared_experts > 0:
        from repro.models.mlp import mlp
        y = y + mlp(x, params["shared"], act)
    return y, aux


def init_moe(key, d_model: int, cfg: MoEConfig, act: str, dtype):
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_ff
    std_in, std_out = d_model ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, e)) * std_in).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (e, d_model, f)) * std_in).astype(dtype),
        "wu": (jax.random.normal(ks[2], (e, d_model, f)) * std_in).astype(dtype),
        "wd": (jax.random.normal(ks[3], (e, f, d_model)) * std_out).astype(dtype),
    }
    if cfg.n_shared_experts > 0:
        from repro.models.mlp import init_mlp
        p["shared"] = init_mlp(ks[4], d_model, cfg.n_shared_experts * f, act, dtype)
    return p


# ---------------------------------------------------------------------------
# All-to-all dispatch expert parallelism (§Perf optimization B)
# ---------------------------------------------------------------------------
#
# The replicated-token EP above needs layer-boundary activations replicated
# along the model axis — the dry-run showed those all-gathers DOMINATE the
# collective term for MoE-heavy stacks (jamba train: ~143 GB/device/step).
# Production MoE systems route tokens with all-to-all instead: tokens stay
# sharded over (data × seq/model); each device sends only its routed tokens
# (t·k/M per peer) to the expert owners and receives them back — wire bytes
# drop from O(full activations × layers) to O(routed tokens × layers).


def _bucket_table(bucket_ids, n_buckets: int, capacity: int):
    """Sort-based dispatch: bucket_ids (N,) → table (n_buckets, capacity) of
    indices into N (sentinel N for empty/overflow slots)."""
    n = bucket_ids.shape[0]
    order = jnp.argsort(bucket_ids, stable=True)
    sorted_b = bucket_ids[order]
    starts = jnp.searchsorted(sorted_b, jnp.arange(n_buckets))
    pos = jnp.arange(n) - starts[sorted_b]
    ok = (pos < capacity) & (sorted_b >= 0) & (sorted_b < n_buckets)
    bi = jnp.where(ok, sorted_b, n_buckets)
    pi = jnp.where(ok, pos, capacity)
    return jnp.full((n_buckets, capacity), n, jnp.int32).at[bi, pi].set(
        order.astype(jnp.int32), mode="drop")


def _local_moe_a2a(x, router, wg, wu, wd, *, cfg: MoEConfig, act: str,
                   e_loc: int, model_axis: str, n_model: int, axes=()):
    """Per-device body; x: (B_loc, S_loc, d) — tokens sharded over data AND
    model (the seq-parallel boundary layout, no replication)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    xt = x.reshape(t, d)

    gates = jax.nn.softmax(xt.astype(jnp.float32) @ router.astype(jnp.float32))
    w, idx = jax.lax.top_k(gates, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    flat_e = idx.reshape(-1)
    flat_w = w.reshape(-1)
    dest = flat_e // e_loc                                # target device
    c_out = max(8, -(-int(t * k / max(n_model, 1) * 1.5) // 8) * 8)

    table = _bucket_table(dest, n_model, c_out)           # (M, c_out) slots
    slot_ok = table < t * k
    tok = jnp.where(slot_ok, table // k, t)
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
    send_x = xpad[tok]                                    # (M, c_out, d)
    epad = jnp.concatenate([flat_e, jnp.full((1,), 0, flat_e.dtype)])
    wpad = jnp.concatenate([flat_w, jnp.zeros((1,), flat_w.dtype)])
    send_e = jnp.where(slot_ok, epad[jnp.minimum(table, t * k)] % e_loc, e_loc)
    send_w = jnp.where(slot_ok, wpad[jnp.minimum(table, t * k)], 0.0)

    recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, model_axis, 0, 0, tiled=True)
    recv_w = jax.lax.all_to_all(send_w, model_axis, 0, 0, tiled=True)

    n_recv = n_model * c_out
    rx = recv_x.reshape(n_recv, d)
    re = recv_e.reshape(n_recv)
    rw = recv_w.reshape(n_recv)

    # second-level (local, no comm) dispatch to this device's experts —
    # c_out is already over-provisioned 1.5×, so no extra factor here
    c2 = max(8, -(-int(n_recv / max(e_loc, 1)) // 8) * 8)
    c2 = min(c2, n_recv)
    table2 = _bucket_table(re, e_loc, c2)                 # (E_loc, c2)
    rxp = jnp.concatenate([rx, jnp.zeros((1, d), rx.dtype)])
    xe = rxp[jnp.minimum(table2, n_recv)]                 # (E_loc, c2, d)
    xe = jnp.where((table2 < n_recv)[..., None], xe, 0)
    if act in ("swiglu", "geglu"):
        h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
            jnp.einsum("ecd,edf->ecf", xe, wu)
    else:
        h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xe, wu))
    ye = jnp.einsum("ecf,efd->ecd", h, wd)
    wtab = jnp.where(table2 < n_recv,
                     jnp.concatenate([rw, jnp.zeros(1)])[
                         jnp.minimum(table2, n_recv)], 0.0)
    ye = (ye.astype(jnp.float32) * wtab[..., None]).astype(x.dtype)

    # scatter back into recv slots, reverse a2a, combine at source
    back = jnp.zeros((n_recv + 1, d), x.dtype).at[
        jnp.minimum(table2, n_recv)].add(ye, mode="drop")[:n_recv]
    back = back.reshape(n_model, c_out, d)
    ret = jax.lax.all_to_all(back, model_axis, 0, 0, tiled=True)
    # tok: (M, c_out) source-token ids (sentinel t) ; ret: (M, c_out, d)
    y = jnp.zeros((t + 1, d), x.dtype).at[tok].add(ret)[:t]

    frac_routed = jnp.zeros((cfg.n_experts,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    aux = cfg.n_experts * jnp.sum(frac_routed * gates.mean(0))
    aux = jax.lax.pmean(aux, axes)  # tokens sharded over data AND model
    return y.reshape(b, s, d), aux


def moe_ffn_a2a(x, params, cfg: MoEConfig, meshctx: MeshCtx, act: str):
    """All-to-all EP MoE.  x: (B, S, d) with S shardable over model."""
    msize = meshctx.model_size
    if msize <= 1 or cfg.n_experts % msize != 0 or x.shape[1] % msize != 0:
        return moe_ffn(x, params, cfg, meshctx, act)      # fallback
    e_loc = cfg.n_experts // msize
    batch_ax = meshctx.dim_axis(x.shape[0], meshctx.batch_axes)
    bspec = P(batch_ax, meshctx.model_axis, None)
    expert_spec = P(meshctx.model_axis, None, None)
    aux_axes = ((meshctx.batch_axes if batch_ax is not None else ())
                + (meshctx.model_axis,))
    body = functools.partial(
        _local_moe_a2a, cfg=cfg, act=act, e_loc=e_loc,
        model_axis=meshctx.model_axis, n_model=msize, axes=aux_axes)
    y, aux = shard_map(
        body, mesh=meshctx.mesh,
        in_specs=(bspec, P(None, None), expert_spec, expert_spec, expert_spec),
        out_specs=(bspec, P()),
        check_vma=False,
    )(x, params["router"], params["wg"], params["wu"], params["wd"])
    if cfg.n_shared_experts > 0:
        from repro.models.mlp import mlp
        y = y + mlp(x, params["shared"], act)
    return y, aux
