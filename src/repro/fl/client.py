"""Generic federated client: local trainable state + a supplied step fn."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Optional


@dataclasses.dataclass
class FLClient:
    cid: int
    trainable: Any                       # pytree
    opt_state: Any
    data_iter: Iterator
    step_fn: Callable                    # (trainable, opt_state, batch) → (t, o, loss)
    upload_pred: Optional[Callable[[str], bool]] = None

    def local_epoch(self, steps: int):
        loss = None
        for _ in range(steps):
            self.trainable, self.opt_state, loss = self.step_fn(
                self.trainable, self.opt_state, next(self.data_iter))
        return loss

    def upload(self):
        from repro import trees
        if self.upload_pred is None:
            return self.trainable
        return trees.select(self.trainable, self.upload_pred)

    def receive(self, aggregated):
        from repro import trees
        self.trainable = trees.merge(self.trainable, aggregated)
