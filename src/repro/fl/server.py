"""Generic federated server: aggregation strategy + channel bookkeeping."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.core.aggregation import fedavg
from repro.wireless import CommLedger, RayleighChannel, tree_bytes


@dataclasses.dataclass
class FLServer:
    channel: Optional[RayleighChannel] = None
    aggregate_fn: Callable = fedavg
    ledger: CommLedger = dataclasses.field(default_factory=CommLedger)

    def round(self, clients: Sequence, weights=None):
        """Collect uploads over the channel, aggregate survivors, broadcast."""
        uploads, reports = [], []
        gains = (self.channel.realize(len(clients))
                 if self.channel else [None] * len(clients))
        for c, g in zip(clients, gains):
            up = c.upload()
            if self.channel is not None:
                rep = self.channel.uplink(tree_bytes(up), gain=g)
                reports.append(rep)
                if rep.outage:
                    continue
            uploads.append(up)
        if self.channel is not None:
            self.ledger.log_round(reports)
        if not uploads:
            return None
        agg = self.aggregate_fn(uploads, weights)
        for c in clients:
            c.receive(agg)
        return agg
