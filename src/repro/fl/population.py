"""Host-resident client population for sampled-cohort federated training.

Production FL draws a small cohort per round from a huge population; until
now the repo's ``n_clients`` WAS the cohort.  The factored LoRA path makes
each client's trainable state a few-KB rank-r tree, so a 10k+ client
population fits comfortably in host RAM — this module keeps it there:

* ``PopulationStore`` — named slots ("trainable", "opt", "pending"), each a
  stacked numpy tree with a leading (n_clients,) axis.  ``gather`` copies
  the sampled rows into a preallocated staging buffer (the
  ``HostBatchStacker`` discipline: allocate once, refill in place, one
  ``jax.device_put`` per round — steady-state rounds do ZERO reallocation)
  and ``scatter`` writes the round's device results back.  The fused
  compiled round body never sees more than the cohort.
* ``ClientSampler`` — seeded per-round cohort selection: ``uniform``
  (without replacement) or ``availability`` (probability ∝ the scenario's
  per-round availability — clients that are reachable get sampled, the
  regime the Federated Fine-Tuning surveys evaluate).  The RNG is stateful
  so the sequence of cohorts is one stream; ``state_dict`` serializes the
  generator for checkpoint resume (mid-stream resume reproduces the
  uninterrupted sampling stream exactly).
* ``PopulationData`` — lazy non-IID client data: each client owns a
  Dirichlet label distribution (``ScenarioTrace.class_probs``) over a
  shared class-bucketed sample pool; batches are drawn by a PURE function
  of (seed, client id, round), so no per-client iterator state exists to
  replay on resume and 10k clients cost O(n_clients × n_classes) memory,
  not 10k materialized datasets.

``PopulationConfig`` is the knob bundle ``run_pftt``/``run_pfit`` accept
(``PFTTConfig(population=...)``); the round loops own the orchestration
(sample → gather → fused round → scatter) and the ``StalenessTracker``
runs population-wide — pending payloads are keyed by population client id,
so a straggler's payload survives rounds it is not sampled in.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import trees
from repro.obs.trace import SpanTracer
from repro.wireless.scenarios import Scenario

SAMPLER_KINDS = ("uniform", "availability")


def _writable(leaf) -> np.ndarray:
    """Host numpy array the store may mutate (``np.asarray`` of a jax
    array is a READ-ONLY view — scatter would fail on it)."""
    a = np.asarray(leaf)
    return a if a.flags.writeable else np.array(a)


@dataclasses.dataclass(frozen=True)
class PopulationConfig:
    """Population-mode knobs for ``run_pftt``/``run_pfit``.

    ``population`` clients live in the host store; every round samples a
    ``cohort_size`` cohort (the compiled round body's client axis — the
    body itself is the same program a ``n_clients=cohort_size`` run
    compiles).  ``scenario`` shapes the population (non-IID partitions,
    availability, mobility — ``wireless/scenarios.py``); ``sampler`` picks
    who participates."""
    population: int
    cohort_size: int
    sampler: str = "uniform"          # uniform | availability
    scenario: Optional[Scenario] = None
    seed: int = 0

    def __post_init__(self):
        if self.sampler not in SAMPLER_KINDS:
            raise ValueError(f"sampler must be one of {SAMPLER_KINDS}, "
                             f"got {self.sampler!r}")
        if not (0 < self.cohort_size <= self.population):
            raise ValueError(
                f"need 0 < cohort_size ({self.cohort_size}) <= "
                f"population ({self.population})")
        if (self.sampler == "availability"
                and not (self.scenario is not None
                         and self.scenario.has_availability())):
            raise ValueError("availability sampler needs a scenario with "
                             "avail != 'none'")


class PopulationStore:
    """Stacked host-numpy client state with buffered gather/scatter.

    Each slot is a pytree whose leaves carry a leading (n_clients,) axis.
    ``gather(slot, ids, pad_to=)`` refills the slot's preallocated staging
    buffer (rows beyond ``len(ids)`` repeat row ``ids[0]`` — the ghost-pad
    convention of ``repro.sharding.CohortSharding``) and returns it;
    callers ``jax.device_put`` the result themselves so sharded and
    single-device paths place it once.  ``scatter(slot, ids, tree)`` pulls
    the device tree to host and writes the first ``len(ids)`` rows back."""

    def __init__(self, slots: Dict[str, object]):
        self._slots = {}
        self._bufs: Dict[str, object] = {}
        n = None
        for name, tree in slots.items():
            tree = jax.tree_util.tree_map(_writable, tree)
            for leaf in jax.tree_util.tree_leaves(tree):
                n = leaf.shape[0] if n is None else n
                assert leaf.shape[0] == n, \
                    f"slot {name!r} leading axis {leaf.shape[0]} != {n}"
            self._slots[name] = tree
        assert n is not None, "empty store"
        self._n = int(n)

    @property
    def n_clients(self) -> int:
        return self._n

    @property
    def slots(self) -> Dict[str, object]:
        return self._slots

    def nbytes(self) -> int:
        return sum(leaf.nbytes
                   for tree in self._slots.values()
                   for leaf in jax.tree_util.tree_leaves(tree))

    def gather(self, slot: str, ids: np.ndarray, pad_to: int = 0):
        """Rows ``ids`` of ``slot`` → the slot's reused staging buffer
        (allocated on first use, refilled in place afterwards)."""
        ids = np.asarray(ids, np.int64)
        k = len(ids)
        rows = max(pad_to, k)
        tree = self._slots[slot]
        buf = self._bufs.get(slot)
        if buf is None or jax.tree_util.tree_leaves(buf)[0].shape[0] != rows:
            buf = jax.tree_util.tree_map(
                lambda l: np.empty((rows,) + l.shape[1:], l.dtype), tree)
            self._bufs[slot] = buf
        # ghost rows repeat the first sampled client (copies, not zeros:
        # they must be numerically well-behaved under the psum)
        full = np.concatenate([ids, np.full(rows - k, ids[0], np.int64)])

        def fill(src, dst):
            np.take(src, full, axis=0, out=dst)
            return dst

        return jax.tree_util.tree_map(fill, tree, buf)

    def scatter(self, slot: str, ids: np.ndarray, device_tree) -> None:
        """Write the first ``len(ids)`` rows of ``device_tree`` back into
        ``slot`` (ghost-padded rows are dropped)."""
        ids = np.asarray(ids, np.int64)
        k = len(ids)

        def put(dst, src):
            # np.array copy, not np.asarray: a zero-copy view of a donated
            # jax buffer dangles once the next round rebinds it
            dst[ids] = np.array(src)[:k]

        jax.tree_util.tree_map(put, self._slots[slot], device_tree)

    def zero_rows(self, slot: str, ids: Sequence[int]) -> None:
        """Zero the given rows (deferred crash-rejoin optimizer reset for
        clients whose rejoin round fell outside a sampled cohort)."""
        ids = np.asarray(ids, np.int64)
        if len(ids) == 0:
            return
        jax.tree_util.tree_map(lambda l: l.__setitem__(ids, 0),
                               self._slots[slot])

    def row(self, slot: str, i: int):
        return jax.tree_util.tree_map(lambda l: l[i], self._slots[slot])

    # ---- checkpointing -----------------------------------------------------

    def checkpoint_tree(self):
        """The whole store as one pytree (slot-name-prefixed) for
        ``checkpoint.ckpt.save_checkpoint``."""
        return dict(self._slots)

    def load_checkpoint_tree(self, tree) -> None:
        for name in self._slots:
            self._slots[name] = jax.tree_util.tree_map(
                _writable, tree[name])


class ClientSampler:
    """Seeded per-round cohort sampling over the population.

    ``uniform``: every client equally likely, without replacement.
    ``availability``: probability ∝ the round's availability probabilities
    (``ScenarioTrace.avail_probs``) — the server preferentially samples
    reachable clients, so diurnal populations induce participation skew.

    One stateful ``RandomState`` drives the whole run: the cohort sequence
    is a single stream, so ``state_dict``/``load_state_dict`` (stored in
    the checkpoint sidecar) make a mid-stream resume reproduce the
    uninterrupted sequence exactly."""

    def __init__(self, kind: str, population: int, cohort_size: int,
                 seed: int = 0):
        if kind not in SAMPLER_KINDS:
            raise ValueError(f"unknown sampler kind {kind!r}")
        self.kind = kind
        self.population = population
        self.cohort_size = cohort_size
        self._rng = np.random.RandomState(seed)

    def sample(self, avail_probs: Optional[np.ndarray] = None) -> np.ndarray:
        """One round's cohort (sorted client ids, without replacement)."""
        if self.kind == "uniform" or avail_probs is None:
            ids = self._rng.choice(self.population, size=self.cohort_size,
                                   replace=False)
        else:
            p = np.asarray(avail_probs, np.float64)
            assert p.shape == (self.population,), p.shape
            p = np.maximum(p, 1e-12)
            ids = self._rng.choice(self.population, size=self.cohort_size,
                                   replace=False, p=p / p.sum())
        return np.sort(ids)

    # ---- checkpoint/resume -------------------------------------------------

    def state_dict(self) -> Dict:
        kind, keys, pos, has_gauss, cached = self._rng.get_state()
        return {"kind": self.kind, "rng": [kind, np.asarray(keys).tolist(),
                                          int(pos), int(has_gauss),
                                          float(cached)]}

    def load_state_dict(self, d: Dict) -> None:
        assert d["kind"] == self.kind, (d["kind"], self.kind)
        kind, keys, pos, has_gauss, cached = d["rng"]
        self._rng.set_state((kind, np.asarray(keys, np.uint32), int(pos),
                             int(has_gauss), float(cached)))


class PopulationData:
    """Lazy non-IID client data over a shared class-bucketed pool.

    The pool is one synthetic corpus; each client draws samples from its
    own label distribution (``class_probs[cid]``) by picking a class, then
    a pool index within that class.  Draws are pure functions of
    (seed, client id, round) — 10k clients need no per-client iterator
    state, and checkpoint resume needs no replay."""

    def __init__(self, pool: Dict[str, np.ndarray], class_probs: np.ndarray,
                 seed: int = 0, label_key: str = "label"):
        self.pool = {k: v for k, v in pool.items()
                     if isinstance(v, np.ndarray) and v.ndim >= 1
                     and len(v) == len(pool[label_key])}
        self.scalars = {k: v for k, v in pool.items()
                        if k not in self.pool}      # e.g. prompt_len
        self.class_probs = np.asarray(class_probs, np.float64)
        self.n_classes = self.class_probs.shape[1]
        self.seed = seed
        labels = pool[label_key]
        self.buckets = [np.where(labels == c)[0]
                        for c in range(self.n_classes)]
        for c, b in enumerate(self.buckets):
            assert len(b) > 0, f"pool has no samples of class {c}"

    def _rng(self, cid: int, tag: int) -> np.random.RandomState:
        # splitmix-style mix keeps client/round streams independent
        h = (self.seed * 0x9E3779B1 + cid * 0x85EBCA77 + tag * 0xC2B2AE3D
             ) & 0xFFFFFFFF
        return np.random.RandomState(h)

    def _draw(self, rng, cid: int, n: int) -> np.ndarray:
        cls = rng.choice(self.n_classes, size=n, p=self.class_probs[cid]
                         / self.class_probs[cid].sum())
        return np.asarray([self.buckets[c][rng.randint(len(self.buckets[c]))]
                           for c in cls], np.int64)

    def round_batches(self, cid: int, rnd: int, local_steps: int,
                      batch: int) -> List[Dict[str, np.ndarray]]:
        """The client's ``local_steps`` training batches for round
        ``rnd`` (deterministic in (seed, cid, rnd))."""
        rng = self._rng(cid, rnd)
        out = []
        for _ in range(local_steps):
            sel = self._draw(rng, cid, batch)
            b = {k: v[sel] for k, v in self.pool.items()}
            b.update(self.scalars)
            out.append(b)
        return out

    def test_set(self, cid: int, n: int) -> Dict[str, np.ndarray]:
        """The client's held-out eval draw (deterministic in (seed, cid);
        tag -1 keeps it off every round's training stream)."""
        rng = self._rng(cid, 0x7FFFFFFF)
        sel = self._draw(rng, cid, n)
        b = {k: v[sel] for k, v in self.pool.items()}
        b.update(self.scalars)
        return b


def stacked_client_init(init_fn, keys) -> object:
    """Vmap a per-client init over stacked PRNG keys → one stacked tree
    (constant leaves broadcast), pulled to host numpy for the store."""
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree_util.tree_map(np.asarray, stacked)


class PopulationRunner:
    """Per-round population orchestration around the fused cohort body.

    The compiled round step (``core.cohort.build_supervised_round`` with
    ``robust=True``) is untouched — it still sees a stacked cohort of
    ``cohort_size`` (+ghost) rows.  Everything population-specific is host
    work this runner owns, in order each round:

    1. **sample** — ``ClientSampler`` draws the cohort (availability-
       weighted from the scenario trace when configured);
    2. **plan** — the ``StalenessTracker`` (sized to the POPULATION, so a
       straggler's pending payload survives rounds it isn't sampled in)
       resolves a population-wide ``RoundPlan`` from the fault trace ∧
       sampled-mask ∧ realized availability;
    3. **gather** — the sampled rows of every store slot refill their
       staging buffers, the current ``global_shared`` tree is overlaid into
       the uploaded subtree (the downlink: participants start from the
       server's global, which also keeps the codec's delta-vs-broadcast
       reference contract), one ``device_put`` per slot;
    4. the **fused round** runs on cohort-indexed slices of the plan;
    5. **scatter** — result rows write back; the new global is read off any
       cohort row whose merge gate passed (host-known from the plan).

    Crash-rejoins that land on unsampled rounds set a ``needs_opt_reset``
    flag; the reset is applied to the store the next time that client is
    gathered.  ``state_dict``/``checkpoint_tree`` capture the whole host
    state (sampler RNG mid-stream, tracker, flags, store, global) so a
    killed run resumes into the uninterrupted sequence."""

    def __init__(self, *, pop: PopulationConfig, store: PopulationStore,
                 global_shared, upload_pred, channel, budget, ledger,
                 tracker, trace, strace, sampler: ClientSampler,
                 arrivals=None, dl=None, cs=None, est_bits=None,
                 act_bits: float = 0.0, tracer=None, health: bool = False):
        self.pop = pop
        self.N = pop.population
        self.K = pop.cohort_size
        self.store = store
        self.global_shared = global_shared
        self.upload_pred = upload_pred
        self.channel = channel
        self.budget = budget
        self.ledger = ledger
        self.tracker = tracker
        self.trace = trace
        self.strace = strace
        self.sampler = sampler
        self.arrivals = arrivals
        self.dl = dl
        self.cs = cs                      # CohortSharding over the cohort
        self.n_rows = cs.total if cs is not None else self.K
        self.est_bits = None if est_bits is None else \
            np.asarray(est_bits, np.float64)
        self.act_bits = float(act_bits)
        self.needs_opt_reset = np.zeros(self.N, bool)
        # the tracer owns all host timing (a disabled tracer still times);
        # host_s/round_s keep their PR 9 meaning: sample+gather+scatter vs
        # whole-round wall
        self.tracer = tracer if tracer is not None else SpanTracer()
        self.health = health              # round_step returns a trailing
        #                                 # health-scalar dict (obs.health)
        self.host_s = 0.0                 # sample+gather+scatter time
        self.round_s = 0.0                # total round wall time
        self.round_wall = []              # per-round wall (round_s addends):
        #                                 # [0] holds the compile, [1:] are
        #                                 # steady-state (obs overhead bench)
        self.seen = np.zeros(self.N, bool)  # ever-sampled coverage

    # ---- helpers -----------------------------------------------------------

    def _put(self, tree):
        return jax.device_put(tree, self.cs.named) \
            if self.cs is not None else jax.device_put(tree)

    def _vec(self, v, fill):
        full = np.concatenate(
            [np.asarray(v, np.float32),
             np.full(self.n_rows - self.K, fill, np.float32)])
        return self._put(full)

    def _overlay_global(self, tr_buf) -> None:
        """Broadcast the server's global into the gathered rows' uploaded
        subtree, in place (numpy staging buffer)."""
        flat_g = trees.flatten(self.global_shared)

        def f(path, leaf):
            g = flat_g.get(path)
            if g is not None:
                leaf[:] = np.asarray(g)
            return leaf

        trees.map_with_path(f, tr_buf)

    def _snapshot_global(self, cid: int):
        row = self.store.row("trainable", cid)
        return jax.tree_util.tree_map(
            np.array, trees.select(row, self.upload_pred))

    # ---- the round ---------------------------------------------------------

    def run_round(self, rnd: int, *, round_step, stacker, draw_batches,
                  local_steps: int, payload_bits: Optional[float] = None,
                  codec_key=None) -> Dict:
        """One sampled-cohort round.  ``draw_batches(cid, rnd)`` returns the
        client's ``local_steps`` host batches; ``payload_bits`` is the
        uncompressed fresh-upload size (ignored under a codec, where the
        fused body reports realized encoded bits); ``codec_key`` is the
        run-level codec PRNG key (per-round/per-CLIENT-ID keys are folded
        here, so a client's stochastic-rounding stream is stable no matter
        which cohorts it lands in)."""
        tracer = self.tracer
        with tracer.span("round") as sp_round:
            with tracer.span("sample") as sp_sample:
                probs = self.strace.avail_probs(rnd) \
                    if self.sampler.kind == "availability" else None
                ids = self.sampler.sample(probs)
                self.seen[ids] = True

            with tracer.span("plan"):
                # population-wide plan: faults ∧ sampled ∧ realized
                # availability
                gains = (self.channel.realize(self.N)
                         * self.strace.gain_round(rnd))
                rf = self.trace.round(rnd)
                gains = gains * rf.gain_scale
                s = np.zeros(self.N, np.float32)
                s[ids] = 1.0
                avail = self.strace.avail_round(rnd)
                rf_pop = dataclasses.replace(
                    rf, train=rf.train * s * avail, tx=rf.tx * s * avail,
                    recv=rf.recv * s * avail, rejoin=rf.rejoin * s)
                # a crash-rejoin on an unsampled round resets the optimizer
                # the next time the client is gathered
                self.needs_opt_reset |= (rf.rejoin > 0) & (s == 0)
                rplan = self.tracker.begin_round(
                    rf_pop, self.channel.outage_weights(gains), gains=gains,
                    fresh_bits=self.est_bits)

            with tracer.span("gather") as sp_gather:
                reset = ids[self.needs_opt_reset[ids]]
                self.store.zero_rows("opt", reset)
                self.needs_opt_reset[ids] = False
                tr_h = self.store.gather("trainable", ids,
                                         pad_to=self.n_rows)
                self._overlay_global(tr_h)
                tr_d = self._put(tr_h)
                opt_d = self._put(self.store.gather("opt", ids,
                                                    pad_to=self.n_rows))
                pend_d = self._put(self.store.gather("pending", ids,
                                                     pad_to=self.n_rows))

            # the batch draw rides inside the device-step window (as it did
            # in the t0..t6 accounting: it is not host_s overhead)
            hstats = None
            with tracer.span("device-step"):
                rows = [draw_batches(int(c), rnd) for c in ids]
                rows += [rows[0]] * (self.n_rows - self.K)   # ghost rows
                batches = stacker(rows)
                w = rplan.agg_w_pre if self.dl is not None else rplan.agg_w
                ontime = rplan.ontime if self.dl is not None \
                    else np.ones(self.N, np.float32)
                margs = (self._vec(rplan.train[ids], 1.0),
                         self._vec(w[ids], 0.0),
                         self._vec(rplan.recv[ids], 1.0),
                         self._vec(rplan.rejoin[ids], 0.0),
                         self._vec(ontime[ids], 1.0))
                if codec_key is None:
                    outs = round_step(tr_d, opt_d, pend_d, batches, *margs)
                    tr_d, opt_d, pend_d, losses = outs[:4]
                    if self.health:
                        hstats = outs[4]
                    fresh_c = np.full(self.K, (payload_bits or 0.0),
                                      np.float64)
                else:
                    with tracer.span("encode"):
                        rk = jax.random.fold_in(codec_key, rnd)
                        ck = jnp.stack(
                            [jax.random.fold_in(rk, int(c)) for c in ids]
                            + [jax.random.fold_in(rk, int(ids[0]))]
                            * (self.n_rows - self.K))
                    outs = round_step(tr_d, opt_d, pend_d, batches, *margs,
                                      self._put(ck))
                    tr_d, opt_d, pend_d, losses, bits = outs[:5]
                    if self.health:
                        hstats = outs[5]
                    fresh_c = (np.asarray(bits, np.float64)[:self.K]
                               + self.act_bits)
                jax.block_until_ready(tr_d)

            with tracer.span("scatter") as sp_scatter:
                self.store.scatter("trainable", ids, tr_d)
                self.store.scatter("opt", ids, opt_d)
                self.store.scatter("pending", ids, pend_d)
                # the merge gate is host-known: extract the new global from
                # any cohort row that received the broadcast
                gate = float(rplan.agg_w.sum()) > 0 and rplan.quorum_ok
                if gate:
                    recv_rows = np.where(rplan.recv[ids] > 0)[0]
                    if len(recv_rows):
                        self.global_shared = self._snapshot_global(
                            int(ids[recv_rows[0]]))

            with tracer.span("ledger"):
                fresh_n = np.zeros(self.N, np.float64)
                fresh_n[ids] = fresh_c
                charged = self.tracker.end_round(rplan, fresh_n)
                extra = None
                if self.dl is not None:
                    extra = {"sim_dt_s": float(rplan.sim_dt_s),
                             "quorum_noop": not rplan.quorum_ok,
                             "n_delivered": int(rplan.n_delivered),
                             "corrupt": int(np.asarray(rplan.corrupt).sum())}
                    if codec_key is not None:  # realized size → next est.
                        self.est_bits = np.where(
                            np.asarray(rplan.train) > 0, fresh_n,
                            self.est_bits)
                att = np.where(np.asarray(rplan.attempt) > 0)[0]
                if self.dl is None:
                    reports = [self.budget.report(charged[ci], gains[ci])
                               for ci in att]
                else:
                    reports = [self.budget.attempt_report(
                        charged[ci], gains[ci],
                        tx_time_s=float(rplan.tx_time_s[ci]),
                        arrival_s=float(rplan.arrival_s[ci]),
                        delivered=bool(rplan.delivered[ci] > 0))
                        for ci in att]
                self.ledger.log_round(reports, extra, round_id=rnd)

        self.host_s += sp_sample.dur + sp_gather.dur + sp_scatter.dur
        self.round_s += sp_round.dur
        self.round_wall.append(sp_round.dur)
        if hstats is not None:
            hstats = {k: float(v) for k, v in hstats.items()}
        return {"ids": ids, "cohort_tr": tr_d, "losses": losses,
                "plan": rplan, "health": hstats}

    def burn_rounds(self, n: int) -> None:
        """Replay the host RNG draws of ``n`` skipped rounds on resume
        (the sampler/tracker restore from state_dict instead)."""
        for _ in range(n):
            self.channel.realize(self.N)
            if self.arrivals is not None:
                self.arrivals.burn_round()

    # ---- checkpoint/resume -------------------------------------------------

    def state_dict(self) -> Dict:
        d = {"sampler": self.sampler.state_dict(),
             "tracker": self.tracker.state_dict(),
             "needs_opt_reset": np.where(self.needs_opt_reset)[0].tolist(),
             "seen": np.where(self.seen)[0].tolist(),
             "host_s": self.host_s, "round_s": self.round_s}
        if self.est_bits is not None:
            d["est_bits"] = [float(b) for b in self.est_bits]
        return d

    def load_state_dict(self, d: Dict) -> None:
        self.sampler.load_state_dict(d["sampler"])
        self.tracker.load_state_dict(d["tracker"])
        self.needs_opt_reset = np.zeros(self.N, bool)
        self.needs_opt_reset[np.asarray(d["needs_opt_reset"],
                                        np.int64)] = True
        self.seen = np.zeros(self.N, bool)
        self.seen[np.asarray(d["seen"], np.int64)] = True
        self.host_s = float(d.get("host_s", 0.0))
        self.round_s = float(d.get("round_s", 0.0))
        if "est_bits" in d:
            self.est_bits = np.asarray(d["est_bits"], np.float64)

    def checkpoint_tree(self):
        return {"store": self.store.checkpoint_tree(),
                "global": self.global_shared}

    def load_checkpoint_tree(self, tree) -> None:
        self.store.load_checkpoint_tree(tree["store"])
        self.global_shared = tree["global"]

    @property
    def host_overhead_frac(self) -> float:
        return self.host_s / self.round_s if self.round_s > 0 else 0.0
