"""Generic federated training loop."""
from __future__ import annotations

from typing import Callable, Optional, Sequence


def run_rounds(server, clients: Sequence, *, rounds: int, local_steps: int,
               eval_fn: Optional[Callable] = None, verbose: bool = False):
    """eval_fn(clients) → scalar metric, recorded per round."""
    history = []
    for rnd in range(rounds):
        for c in clients:
            c.local_epoch(local_steps)
        server.round(clients)
        if eval_fn is not None:
            m = eval_fn(clients)
            history.append(m)
            if verbose:
                print(f"round {rnd}: {m:.4f}")
    return history
