from repro.fl.client import FLClient  # noqa: F401
from repro.fl.server import FLServer  # noqa: F401
from repro.fl.rounds import run_rounds  # noqa: F401
from repro.fl.population import (  # noqa: F401
    ClientSampler, PopulationConfig, PopulationData, PopulationRunner,
    PopulationStore)
