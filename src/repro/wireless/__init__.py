from repro.wireless.channel import RayleighChannel, ChannelReport  # noqa: F401
from repro.wireless.cost import CommLedger, tree_bytes  # noqa: F401
from repro.wireless.faults import FaultPlan, FaultTrace, RoundFaults  # noqa: F401
from repro.wireless.arrivals import ArrivalModel, DeadlineConfig  # noqa: F401
from repro.wireless.scenarios import Scenario, ScenarioTrace  # noqa: F401
