"""Wireless uplink simulation (paper §V-A: Rayleigh channel, SNR = 5 dB,
40 communication rounds).

Block Rayleigh fading per client per round: channel gain |h|² ~ Exp(1),
instantaneous SNR γ = γ̄·|h|².  Achievable rate follows Shannon capacity
R = W·log2(1+γ).  A client is in *outage* for the round when γ falls below
``outage_snr_db`` — its update is lost (the server reuses the previous global
for that slot).  Upload delay = payload bits / R.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class ChannelReport:
    snr_db: float
    rate_bps: float
    delay_s: float
    outage: bool
    bytes_sent: float
    energy_j: float = 0.0     # transmit energy; filled by comms.ChannelBudget


@dataclasses.dataclass
class RayleighChannel:
    mean_snr_db: float = 5.0
    bandwidth_hz: float = 1e6
    outage_snr_db: float = -5.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    def realize(self, n_clients: int) -> np.ndarray:
        """Per-client |h|² draws for one round."""
        return self._rng.exponential(1.0, size=n_clients)

    def snr(self, gain):
        """Gain draw(s) → (snr_db, snr_linear); scalar or vectorized — the
        ONE place the fading → SNR mapping lives (``uplink`` and
        ``outage_weights`` must agree on it)."""
        snr_lin = 10 ** (self.mean_snr_db / 10.0) * np.asarray(gain)
        snr_db = 10 * np.log10(np.maximum(snr_lin, 1e-12))
        return snr_db, snr_lin

    def outage_weights(self, gains: np.ndarray) -> np.ndarray:
        """Vectorized 1/0 alive-weight vector for one round of ``gains`` —
        the cohort engine's aggregation weights (0 = outage, the client's
        update is dropped from the weighted mean).  Same decision as the
        per-client ``uplink``."""
        snr_db, _ = self.snr(gains)
        return (snr_db >= self.outage_snr_db).astype(np.float32)

    def uplink(self, payload_bytes: float, gain: Optional[float] = None
               ) -> ChannelReport:
        """``payload_bytes`` may be fractional (entropy-coded payloads —
        see ``repro.comms``); delay charges the exact bit count."""
        if gain is None:
            gain = float(self._rng.exponential(1.0))
        snr_db, snr_lin = self.snr(gain)
        rate = self.bandwidth_hz * np.log2(1.0 + snr_lin)
        outage = snr_db < self.outage_snr_db
        delay = np.inf if outage else payload_bytes * 8.0 / max(rate, 1.0)
        return ChannelReport(snr_db=float(snr_db), rate_bps=float(rate),
                             delay_s=float(delay), outage=bool(outage),
                             bytes_sent=0 if outage else payload_bytes)
