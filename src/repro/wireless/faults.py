"""Seeded fault injection for the federated runtime (paper §VI-1: wireless
clients fade, stall, and drop mid-round).

A ``FaultPlan`` is a frozen, seeded *specification* of client failure rates;
``FaultPlan.realize(n_clients, rounds)`` expands it into a ``FaultTrace`` —
concrete per-round, per-client availability arrays — so every failure mode
is exactly reproducible across the fused cohort engine, the legacy
per-client loop (the parity oracle), tests, and benchmarks.

Failure modes (per client, per round; priority crash > straggle > dropout):

* **dropout** — the client misses the round entirely: no local training, no
  uplink, no broadcast received.  One round, memoryless.
* **straggle-by-k** — the client's round-``r`` local update takes ``1+k``
  round-times to compute + deliver: it trains at round ``r``, stays busy
  (no training, no uplink) through ``r+1 … r+k-1``, and its round-``r``
  payload goes on the air at round ``r+k`` with staleness ``k``.  The
  bounded-staleness engine merges it with the ``α·(1+k)^(-a)`` discount;
  the synchronous engine would have gated the whole cohort on it.
* **crash-and-rejoin** — the client disappears for ``d`` rounds (no train /
  tx / recv; any pending payload is lost) and rejoins from the current
  broadcast global with freshly zeroed optimizer state.
* **SNR dip** — the client's Rayleigh gain is scaled down by ``dip_db`` for
  the round; deep dips push the realized SNR below
  ``RayleighChannel.outage_snr_db`` and trigger the retransmission path.
* **corruption** — the client's delivered payload is corrupted in transit
  for the round: the server's checksum (``comms.codec.payload_checksum``)
  rejects it, the delivery is NACKed into the retransmission path and never
  merged.  Memoryless per round, like dropout; only observable on rounds
  the client actually puts a payload on the air.

The trace deliberately stays *channel-independent*: it scales the fading
gains (``gain_scale``) and gates the uplink (``tx``), but outage decisions
remain ``RayleighChannel``'s — the same plan replays identically under any
channel seed.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class RoundFaults:
    """One round's realized fault state (all (n_clients,) float32 arrays;
    1.0 = yes).  ``gain_scale`` multiplies the round's Rayleigh draws."""
    train: np.ndarray        # client runs local steps this round
    tx: np.ndarray           # client may put a payload on the air
    recv: np.ndarray         # client receives the broadcast global
    rejoin: np.ndarray       # client rejoins after a crash (reset opt state,
                             # drop pre-crash pending payload)
    gain_scale: np.ndarray   # multiplies the Rayleigh |h|² draw (SNR dips)
    # the two continuous-time fields default to None (= no corruption,
    # unit compute scale) so round-granular consumers and hand-built
    # RoundFaults keep working unchanged
    corrupt: Optional[np.ndarray] = None       # payload corrupted in transit
    compute_scale: Optional[np.ndarray] = None  # straggle factor for the
                                               # compute-time draw (1 + k on
                                               # straggle rounds)


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """Realized per-round, per-client availability arrays (all
    (rounds, n_clients); see ``RoundFaults`` for per-field semantics)."""
    train: np.ndarray
    tx: np.ndarray
    recv: np.ndarray
    rejoin: np.ndarray
    gain_scale: np.ndarray
    corrupt: Optional[np.ndarray] = None
    compute_scale: Optional[np.ndarray] = None

    @property
    def rounds(self) -> int:
        return self.train.shape[0]

    @property
    def n_clients(self) -> int:
        return self.train.shape[1]

    def round(self, r: int) -> RoundFaults:
        """Clamp past the planned horizon to fault-free (long runs keep
        going; the plan covers the rounds it was realized for)."""
        if r >= self.rounds:
            n = self.n_clients
            one = np.ones((n,), np.float32)
            return RoundFaults(train=one, tx=one, recv=one,
                               rejoin=np.zeros((n,), np.float32),
                               gain_scale=one.copy(),
                               corrupt=np.zeros((n,), np.float32),
                               compute_scale=one.copy())
        return RoundFaults(
            train=self.train[r], tx=self.tx[r],
            recv=self.recv[r], rejoin=self.rejoin[r],
            gain_scale=self.gain_scale[r],
            corrupt=None if self.corrupt is None else self.corrupt[r],
            compute_scale=(None if self.compute_scale is None
                           else self.compute_scale[r]))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded fault specification; ``realize`` makes it a ``FaultTrace``.

    Rates are per-client, per-round probabilities.  ``FaultPlan()`` is the
    zero-fault plan (every mask all-ones — the parity baseline)."""
    dropout_p: float = 0.0
    straggle_p: float = 0.0
    max_straggle: int = 3        # straggle lag k ~ uniform{1..max_straggle}
    crash_p: float = 0.0
    max_crash: int = 4           # crash length d ~ uniform{1..max_crash}
    snr_dip_p: float = 0.0
    snr_dip_db: float = 20.0     # gain scaled by 10^(-dip/10) on dip rounds
    corrupt_p: float = 0.0       # payload corrupted in transit (checksum NACK)
    seed: int = 0

    def is_zero(self) -> bool:
        return (self.dropout_p == 0 and self.straggle_p == 0
                and self.crash_p == 0 and self.snr_dip_p == 0
                and self.corrupt_p == 0)

    def realize(self, n_clients: int, rounds: int) -> FaultTrace:
        rng = np.random.RandomState(self.seed)
        shape = (rounds, n_clients)
        train = np.ones(shape, np.float32)
        tx = np.ones(shape, np.float32)
        recv = np.ones(shape, np.float32)
        rejoin = np.zeros(shape, np.float32)
        gain_scale = np.ones(shape, np.float32)
        corrupt = np.zeros(shape, np.float32)
        compute_scale = np.ones(shape, np.float32)

        # per-client state machines, advanced round-major so a fixed seed
        # yields one canonical trace regardless of the consumer
        busy = np.zeros(n_clients, np.int64)     # straggle rounds remaining
        down = np.zeros(n_clients, np.int64)     # crash rounds remaining
        for r in range(rounds):
            # one draw block per round keeps the stream layout stable
            u_crash = rng.rand(n_clients)
            d_crash = rng.randint(1, self.max_crash + 1, n_clients)
            u_strag = rng.rand(n_clients)
            k_strag = rng.randint(1, self.max_straggle + 1, n_clients)
            u_drop = rng.rand(n_clients)
            u_dip = rng.rand(n_clients)
            # the corruption block is only drawn when the mode is enabled,
            # so every pre-existing plan replays its exact PR 6 trace
            u_corr = rng.rand(n_clients) if self.corrupt_p > 0 else None
            if u_corr is not None:
                corrupt[r] = (u_corr < self.corrupt_p).astype(np.float32)
            for c in range(n_clients):
                if u_dip[c] < self.snr_dip_p:
                    gain_scale[r, c] = 10.0 ** (-self.snr_dip_db / 10.0)
                if down[c] > 0:                      # mid-crash
                    down[c] -= 1
                    train[r, c] = tx[r, c] = recv[r, c] = 0.0
                    if down[c] == 0:                 # rejoin THIS round:
                        rejoin[r, c] = 1.0           # resync from global,
                        recv[r, c] = 1.0             # train again next round
                    continue
                if busy[c] > 0:                      # mid-straggle
                    busy[c] -= 1
                    train[r, c] = 0.0
                    # still computing → nothing on the air until done; on
                    # the delivery round the client is back online (tx its
                    # stale payload, recv the broadcast)
                    still = busy[c] > 0
                    tx[r, c] = 0.0 if still else 1.0
                    recv[r, c] = 0.0 if still else 1.0
                    continue
                if u_crash[c] < self.crash_p:        # crash starts
                    down[c] = int(d_crash[c])
                    train[r, c] = tx[r, c] = recv[r, c] = 0.0
                    continue
                if u_strag[c] < self.straggle_p:     # straggle starts: train
                    busy[c] = int(k_strag[c])        # now, deliver at r+k
                    tx[r, c] = 0.0
                    # continuous-time view of the same event: the local
                    # update takes 1+k round-times of compute
                    compute_scale[r, c] = 1.0 + float(k_strag[c])
                    continue
                if u_drop[c] < self.dropout_p:       # plain missed round
                    train[r, c] = tx[r, c] = recv[r, c] = 0.0
        return FaultTrace(train=train, tx=tx, recv=recv, rejoin=rejoin,
                          gain_scale=gain_scale, corrupt=corrupt,
                          compute_scale=compute_scale)

    # ---- serialization (launch flags, benchmark manifests) ----------------

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FaultPlan fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_spec(cls, spec: Optional[str]) -> Optional["FaultPlan"]:
        """Parse a CLI spec: ``None``/"none" → no plan; a path to a JSON
        file of ``to_dict`` fields; or an inline ``k=v,k=v`` string, e.g.
        ``dropout_p=0.3,straggle_p=0.2,max_straggle=4,seed=1``."""
        if spec is None or spec == "" or spec == "none":
            return None
        if os.path.exists(spec):
            with open(spec) as f:
                return cls.from_dict(json.load(f))
        d: Dict = {}
        for item in spec.split(","):
            k, _, v = item.partition("=")
            if not _:
                raise ValueError(f"bad fault-plan item {item!r} "
                                 "(want key=value)")
            k = k.strip()
            d[k] = (int(v) if k in ("max_straggle", "max_crash", "seed")
                    else float(v))
        return cls.from_dict(d)
