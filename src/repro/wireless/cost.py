"""Communication-cost accounting (the paper's Figs. 4/5 right panels)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import numpy as np


def tree_bytes(tree, *, nonzero_mask=None, itemsize=None) -> float:
    """Bytes of a pytree payload.

    ``nonzero_mask`` (same *structure* of 1/0 float masks, broadcastable per
    leaf): masked-out parameters are not transmitted (the paper's
    sparse-attention upload saving).  Masks are paired with leaves by
    treedef (``tree_map``), so a structure mismatch raises instead of
    silently misaligning.

    ``itemsize`` overrides the per-element byte width (quantized leaves are
    not ``x.dtype.itemsize`` bytes): a number applies to every leaf, or a
    same-structure pytree gives a per-leaf override (``None``/missing
    entries fall back to the leaf dtype)."""
    from repro import trees as _trees

    flat = _trees.flatten(tree)
    masks = {}
    if nonzero_mask is not None:
        if (jax.tree_util.tree_structure(nonzero_mask)
                != jax.tree_util.tree_structure(tree)):
            raise ValueError(
                "tree_bytes: nonzero_mask structure does not match tree — "
                f"{jax.tree_util.tree_structure(nonzero_mask)} vs "
                f"{jax.tree_util.tree_structure(tree)}")
        masks = _trees.flatten(nonzero_mask)
    if itemsize is None:
        override = {}
    elif isinstance(itemsize, (int, float)):
        override = {p: float(itemsize) for p in flat}
    else:
        override = {p: float(v) for p, v in _trees.flatten(itemsize).items()
                    if v is not None}

    total = 0.0
    for p, x in flat.items():
        if not hasattr(x, "size"):
            continue
        frac = 1.0
        if p in masks:
            m = np.asarray(masks[p])
            frac = float(m.mean()) if m.size else 1.0
        total += round(x.size * frac) * override.get(p, x.dtype.itemsize)
    return int(total) if float(total).is_integer() else total


@dataclasses.dataclass
class CommLedger:
    """Per-round, per-client record of upload traffic, delay and energy."""
    rounds: List[Dict] = dataclasses.field(default_factory=list)

    def log_round(self, reports, extra=None, *, round_id=None):
        # an all-outage round has no completed upload: its delay is
        # undefined (NaN), not 0.0 — mean_round_delay skips it
        alive = [r.delay_s for r in reports if not r.outage]
        rec = {
            # explicit join keys: record_id is the monotonic append index,
            # round is the caller's round counter (defaults to record_id for
            # callers without one) — downstream joins must not rely on list
            # position across quorum-noop/void rounds
            "record_id": len(self.rounds),
            "round": int(round_id) if round_id is not None
            else len(self.rounds),
            "bytes": sum(r.bytes_sent for r in reports),
            "delay_s": max(alive) if alive else float("nan"),
            "energy_j": sum(getattr(r, "energy_j", 0.0) for r in reports),
            "outages": sum(r.outage for r in reports),
            "per_client": [dataclasses.asdict(r) for r in reports],
        }
        if extra:   # continuous-time round extras (sim_dt_s, quorum_noop,
            rec.update(extra)  # corrupt …) — see core/robust.py
        self.rounds.append(rec)

    @property
    def total_bytes(self) -> float:
        return sum(r["bytes"] for r in self.rounds)

    @property
    def total_energy_j(self) -> float:
        return sum(r.get("energy_j", 0.0) for r in self.rounds)

    @property
    def mean_round_bytes(self) -> float:
        return self.total_bytes / max(len(self.rounds), 1)

    @property
    def mean_round_delay(self) -> float:
        vals = [r["delay_s"] for r in self.rounds
                if not np.isnan(r["delay_s"])]
        return float(np.mean(vals)) if vals else 0.0

    # ---- continuous-time round extras (deadline mode) ---------------------

    @property
    def total_sim_time_s(self) -> float:
        """Simulated wall-clock across rounds (deadline mode: the server
        closes each round at its deadline, or at the last arrival when
        waiting for everyone)."""
        return sum(r.get("sim_dt_s", 0.0) for r in self.rounds)

    @property
    def quorum_noops(self) -> int:
        return sum(1 for r in self.rounds if r.get("quorum_noop", False))
