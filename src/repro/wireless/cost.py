"""Communication-cost accounting (the paper's Figs. 4/5 right panels)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import numpy as np


def tree_bytes(tree, *, nonzero_mask=None) -> int:
    """Bytes of a pytree payload.  With ``nonzero_mask`` (same structure of
    1/0 float masks), masked-out parameters are not transmitted (the paper's
    sparse-attention upload saving)."""
    total = 0
    leaves = jax.tree_util.tree_leaves(tree)
    if nonzero_mask is None:
        for x in leaves:
            if hasattr(x, "size"):
                total += int(x.size) * x.dtype.itemsize
        return total
    masks = jax.tree_util.tree_leaves(nonzero_mask)
    for x, m in zip(leaves, masks):
        if not hasattr(x, "size"):
            continue
        m = np.asarray(m)
        frac = float(m.mean()) if m.size else 1.0
        total += int(round(x.size * frac)) * x.dtype.itemsize
    return total


@dataclasses.dataclass
class CommLedger:
    """Per-round, per-client record of upload traffic and delay."""
    rounds: List[Dict] = dataclasses.field(default_factory=list)

    def log_round(self, reports):
        self.rounds.append({
            "bytes": sum(r.bytes_sent for r in reports),
            "delay_s": max((r.delay_s for r in reports
                            if not r.outage), default=0.0),
            "outages": sum(r.outage for r in reports),
            "per_client": [dataclasses.asdict(r) for r in reports],
        })

    @property
    def total_bytes(self) -> int:
        return sum(r["bytes"] for r in self.rounds)

    @property
    def mean_round_bytes(self) -> float:
        return self.total_bytes / max(len(self.rounds), 1)

    @property
    def mean_round_delay(self) -> float:
        return float(np.mean([r["delay_s"] for r in self.rounds])) \
            if self.rounds else 0.0
