"""Continuous-time upload arrivals for the robust federated round.

PR 6's fault runtime discretized failure into round-granular traces, so a
``straggle-by-k`` client was an *input*.  This module closes the loop the
ROADMAP names ("drive staleness from realized ``ChannelBudget`` delays"):
each attempting client gets a per-round **arrival time**

    arrival_s = start_s + payload_bits / realized_rate

where ``realized_rate`` is the round's Shannon rate at the client's realized
Rayleigh SNR (``RayleighChannel.snr`` — the SAME fading → SNR map the outage
decision uses), ``payload_bits`` is the encoded size of the payload on the
air (the client's fresh encode, or the buffered bits of a retransmission),
and ``start_s`` is a compute-time draw scaled by the fault trace's straggle
factor (fresh uploads) or the remaining exponential-backoff wait
(retransmissions).  The server aggregates whoever arrives before
``DeadlineConfig.deadline_s``; late payloads go pending with staleness =
rounds-elapsed-at-delivery — ``straggle-by-k`` becomes an *emergent*
outcome of a slow channel instead of an input.

Scheduling uses the payload size the host knows *when the round is
dispatched*: exact for uncompressed uploads and for retransmissions (the
buffered size), and the client's previously realized encoded size for
compressed fresh uploads (round 0 falls back to the shape-only
``payload_bits_upper_bound``) — the radio reserves its slot from the size
the client reports, while the ledger always charges the realized bits.

Retries (outage, deadline miss, or checksum NACK) follow capped exponential
backoff: the n-th failure of a payload schedules its next attempt no
earlier than ``t_fail + backoff_base_s · 2^(n-1)``, each attempt's airtime
energy is charged to the ledger, and the payload is abandoned (its bits
drop out of the ledger) after ``max_retries`` failed retransmissions.

``min_quorum`` is the graceful-degradation gate: a round delivering fewer
payloads than the quorum becomes an accuracy-preserving no-op — nothing is
merged, deliveries are NACKed back to pending (no backoff penalty: the
abort is the server's, not the channel's), and the event is recorded in the
ledger.  ``min_quorum=0`` reduces to the all-outage ``Σw > 0`` gate.

All decisions are pure functions of host-known quantities (trace masks,
realized gains, known payload sizes), so the fused engine and the legacy
per-client loop consume identical masks/weights from one
``StalenessTracker`` — engine-vs-loop parity stays exact under deadlines.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeadlineConfig:
    """Server-side deadline + retry knobs for the continuous-time round.

    The all-default config ``is_inert()``: an infinite deadline with no
    quorum, no backoff and zero compute time is byte-for-byte the PR 6
    round-granular robust runtime (the runners skip the arrival machinery
    entirely), so ``DeadlineConfig()`` is always safe to thread through."""
    deadline_s: float = math.inf   # aggregation cutoff per round (seconds)
    backoff_base_s: float = 0.0    # n-th failure retries after base·2^(n-1)
    max_retries: int = 8           # failed retransmissions before abandoning
    min_quorum: int = 0            # deliveries below this → no-op round
    compute_mean_s: float = 0.0    # mean local-compute time before the uplink
    seed: int = 0                  # compute-jitter draw stream

    def is_inert(self) -> bool:
        return (math.isinf(self.deadline_s) and self.min_quorum == 0
                and self.backoff_base_s == 0.0 and self.compute_mean_s == 0.0)

    # ---- serialization (launch flags, benchmark manifests) ----------------

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "DeadlineConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown DeadlineConfig fields {sorted(unknown)}; "
                f"known: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_spec(cls, spec: Optional[str]) -> Optional["DeadlineConfig"]:
        """``None``/""/"none" → no config; a JSON file path; or an inline
        ``k=v,k=v`` string, e.g. ``deadline_s=0.5,min_quorum=2``
        (``deadline_s=inf`` parses)."""
        if spec is None or spec == "" or spec == "none":
            return None
        if os.path.exists(spec):
            with open(spec) as f:
                return cls.from_dict(json.load(f))
        d: Dict = {}
        for item in spec.split(","):
            k, sep, v = item.partition("=")
            if not sep:
                raise ValueError(f"bad deadline item {item!r} "
                                 "(want key=value)")
            k = k.strip()
            d[k] = (int(v) if k in ("max_retries", "min_quorum", "seed")
                    else float(v))
        return cls.from_dict(d)


class ArrivalModel:
    """Seeded per-round arrival-time draws against a ``RayleighChannel``.

    One fixed-size draw block per round (``compute_times``) keeps the RNG
    stream layout identical across the engine and the legacy loop and lets
    checkpoint resume replay skipped rounds by burning draws, exactly like
    the channel's fading stream."""

    def __init__(self, channel, cfg: DeadlineConfig, n_clients: int):
        self.channel = channel
        self.cfg = cfg
        self.n_clients = n_clients
        self._rng = np.random.RandomState(cfg.seed)

    def rates(self, gains: np.ndarray) -> np.ndarray:
        """Realized Shannon rate (bps) per client, floored at 1 bps — the
        same ``bits / max(rate, 1)`` floor ``RayleighChannel.uplink``
        charges, so airtime and delay agree."""
        _, snr_lin = self.channel.snr(gains)
        rate = self.channel.bandwidth_hz * np.log2(1.0 + snr_lin)
        return np.maximum(rate, 1.0).astype(np.float64)

    def compute_times(self, compute_scale=None) -> np.ndarray:
        """One round's local-compute draw per client:
        ``compute_mean_s · U[0.5, 1.5) · straggle_scale``.  The uniform
        jitter is drawn for every client every round (stream stability);
        ``compute_scale`` is the trace's per-round straggle factor
        (``1 + k`` on straggle rounds, 1 otherwise)."""
        u = self._rng.rand(self.n_clients)
        ct = self.cfg.compute_mean_s * (0.5 + u)
        if compute_scale is not None:
            ct = ct * np.asarray(compute_scale, np.float64)
        return ct

    def burn_round(self) -> None:
        """Consume one round's draws (checkpoint-resume replay)."""
        self._rng.rand(self.n_clients)

    def backoff_wait_s(self, failures: np.ndarray) -> np.ndarray:
        """Wait before the next attempt after ``failures`` failed attempts
        of the current payload: ``base · 2^(failures-1)`` (0 for an
        unfailed payload)."""
        f = np.asarray(failures, np.float64)
        return np.where(f > 0,
                        self.cfg.backoff_base_s * 2.0 ** np.maximum(f - 1, 0),
                        0.0)
