"""Scenario generators for population-scale wireless FL.

A ``Scenario`` is a frozen, seeded *specification* of the cell the
population lives in; ``Scenario.realize(n_clients, rounds)`` expands it to
a ``ScenarioTrace`` — concrete per-client, per-round arrays — so a
population run is exactly reproducible across the fused engine, tests,
benchmarks, and checkpoint resume (the trace is a pure function of the
spec, never of consumption order).  Three independent axes compose:

* **non-IID data** (``alpha``): each client's label distribution is a
  Dirichlet(α) draw over the task's classes (paper §V-B.2 at population
  scale).  ``alpha=inf`` (the default) is IID — every client samples
  classes uniformly.  The draw lives in ``ScenarioTrace.class_probs``
  ((n_clients, n_classes)); the data layer samples each client's batches
  from it.
* **availability** (``avail``): per-round participation probability.
  ``diurnal`` gives each client a phase-shifted sinusoid (devices cycle
  through day/night reachability, as the cross-device FL literature
  models); ``periodic`` is a hard duty-cycled on/off window.  The trace
  carries both the probability (``avail_p`` — what availability-weighted
  *sampling* uses) and the seeded realization (``avail`` 0/1 — a sampled
  but unavailable client behaves like a dropout fault for the round).
* **mobility** (``mobility="waypoint"``): clients move through the cell
  under the random-waypoint model; distance to the base station maps to a
  path-loss gain ``(ref_m / max(d, ref_m))^pathloss_exp`` that multiplies
  the round's Rayleigh draw — exactly like ``FaultPlan``'s SNR dips, so
  the realized SNR (and therefore outage, Shannon rate, and the
  continuous-time ``ArrivalModel``'s arrival clock) follows the
  trajectory.  Cell-edge clients fade, returning clients recover.

The trace deliberately stays channel-independent (it scales gains; outage
and rate decisions remain ``RayleighChannel``'s) and fault-independent
(an injected ``FaultPlan`` composes on top: masks AND, gain scales
multiply).

Spec grammar (``Scenario.from_spec`` — the ``--scenario`` launch flag):
``k=v`` pairs separated by commas, or a path to a JSON file of
``to_dict`` fields, e.g.::

    alpha=0.1,avail=diurnal,avail_period=8,mobility=waypoint,seed=3

Unknown keys raise (same contract as ``FaultPlan.from_spec``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional

import numpy as np

AVAIL_KINDS = ("none", "diurnal", "periodic")
MOBILITY_KINDS = ("none", "waypoint")


@dataclasses.dataclass(frozen=True)
class ScenarioTrace:
    """Realized per-client scenario arrays.

    ``class_probs`` is (n_clients, n_classes); the per-round arrays are
    (rounds, n_clients).  ``round(r)`` clamps past the planned horizon to
    the benign state (available, unit gain) so longer runs keep going."""
    class_probs: np.ndarray   # (n, n_classes) per-client label distribution
    avail_p: np.ndarray       # (rounds, n) availability probability
    avail: np.ndarray         # (rounds, n) seeded 0/1 realization
    gain_scale: np.ndarray    # (rounds, n) mobility path-loss multiplier

    @property
    def rounds(self) -> int:
        return self.avail.shape[0]

    @property
    def n_clients(self) -> int:
        return self.avail.shape[1]

    def avail_probs(self, r: int) -> np.ndarray:
        if r >= self.rounds:
            return np.ones(self.n_clients, np.float64)
        return self.avail_p[r]

    def avail_round(self, r: int) -> np.ndarray:
        if r >= self.rounds:
            return np.ones(self.n_clients, np.float32)
        return self.avail[r]

    def gain_round(self, r: int) -> np.ndarray:
        if r >= self.rounds:
            return np.ones(self.n_clients, np.float32)
        return self.gain_scale[r]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Seeded population-scenario specification; ``realize`` makes it a
    ``ScenarioTrace``.  ``Scenario()`` is the inert scenario: IID data,
    always-available clients, static unit-gain geometry."""
    alpha: float = math.inf      # Dirichlet label concentration (inf = IID)
    n_classes: int = 4
    avail: str = "none"          # none | diurnal | periodic
    avail_period: float = 24.0   # rounds per availability cycle
    avail_duty: float = 0.5      # periodic: fraction of the cycle online
    avail_min: float = 0.05      # diurnal: floor probability (never 0 —
                                 # availability-weighted sampling stays
                                 # well-defined for every client)
    mobility: str = "none"       # none | waypoint
    cell_m: float = 500.0        # square cell edge, base station centered
    speed_mps: float = 1.5       # random-waypoint speed
    round_s: float = 60.0        # simulated seconds of motion per round
    ref_m: float = 100.0         # path-loss reference distance (unit gain)
    pathloss_exp: float = 3.0
    seed: int = 0

    def __post_init__(self):
        if self.avail not in AVAIL_KINDS:
            raise ValueError(f"avail must be one of {AVAIL_KINDS}, "
                             f"got {self.avail!r}")
        if self.mobility not in MOBILITY_KINDS:
            raise ValueError(f"mobility must be one of {MOBILITY_KINDS}, "
                             f"got {self.mobility!r}")

    def is_inert(self) -> bool:
        return (math.isinf(self.alpha) and self.avail == "none"
                and self.mobility == "none")

    def has_availability(self) -> bool:
        return self.avail != "none"

    # ---- realization -------------------------------------------------------

    def realize(self, n_clients: int, rounds: int) -> ScenarioTrace:
        # one independent RNG stream per axis: enabling one axis never
        # perturbs another's draws, AND each axis's per-round draws are
        # prefix-stable in ``rounds`` (a run re-realized with a longer
        # horizon reproduces the shorter run's rows — the kill/resume and
        # extend-the-run contracts depend on it)
        def stream(tag):
            return np.random.RandomState((self.seed * 0x9E3779B1 + tag)
                                         & 0xFFFFFFFF)

        class_probs = self._realize_class_probs(n_clients, stream(1))
        avail_p, avail = self._realize_availability(n_clients, rounds,
                                                    stream(2))
        gain_scale = self._realize_mobility(n_clients, rounds, stream(3))
        return ScenarioTrace(class_probs=class_probs, avail_p=avail_p,
                             avail=avail, gain_scale=gain_scale)

    def _realize_class_probs(self, n: int, rng) -> np.ndarray:
        if math.isinf(self.alpha):
            return np.full((n, self.n_classes), 1.0 / self.n_classes,
                           np.float64)
        return rng.dirichlet([self.alpha] * self.n_classes, size=n)

    def _realize_availability(self, n: int, rounds: int, rng):
        phase = rng.rand(n)           # drawn even when avail="none" (stream
        u = rng.rand(rounds, n)       # stability across spec edits)
        if self.avail == "none":
            p = np.ones((rounds, n), np.float64)
        else:
            t = np.arange(rounds, dtype=np.float64)[:, None] \
                / max(self.avail_period, 1e-9) + phase[None, :]
            if self.avail == "diurnal":
                p = self.avail_min + (1.0 - self.avail_min) \
                    * 0.5 * (1.0 + np.sin(2.0 * np.pi * t))
            else:                      # periodic: hard duty-cycle window
                p = (np.mod(t, 1.0) < self.avail_duty).astype(np.float64)
                p = np.maximum(p, self.avail_min)
        return p, (u < p).astype(np.float32)

    def _realize_mobility(self, n: int, rounds: int, rng) -> np.ndarray:
        if self.mobility == "none":
            return np.ones((rounds, n), np.float32)
        # random waypoint in a square cell, base station at the center:
        # each client walks toward its waypoint at speed·round_s per round
        # and redraws the waypoint on arrival
        half = self.cell_m / 2.0
        pos = rng.uniform(-half, half, size=(n, 2))
        wp = rng.uniform(-half, half, size=(n, 2))
        step = self.speed_mps * self.round_s
        gain = np.ones((rounds, n), np.float32)
        for r in range(rounds):
            d = np.linalg.norm(pos, axis=1)
            gain[r] = (self.ref_m
                       / np.maximum(d, self.ref_m)) ** self.pathloss_exp
            vec = wp - pos
            dist = np.linalg.norm(vec, axis=1)
            arrive = dist <= step
            move = np.divide(vec, np.maximum(dist, 1e-9)[:, None]) * step
            pos = np.where(arrive[:, None], wp, pos + move)
            # redraw every client's next waypoint each round (fixed-size
            # block keeps the stream stable); only arrivals consume theirs
            nxt = rng.uniform(-half, half, size=(n, 2))
            wp = np.where(arrive[:, None], nxt, wp)
        return gain

    # ---- serialization (launch flags, benchmark manifests) ----------------

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "Scenario":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown Scenario fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_spec(cls, spec: Optional[str]) -> Optional["Scenario"]:
        """``None``/""/"none" → no scenario; a JSON file path; or an inline
        ``k=v,k=v`` string, e.g. ``alpha=0.1,avail=diurnal,seed=3``
        (``alpha=inf`` parses)."""
        if spec is None or spec == "" or spec == "none":
            return None
        if os.path.exists(spec):
            with open(spec) as f:
                return cls.from_dict(json.load(f))
        d: Dict = {}
        for item in spec.split(","):
            k, sep, v = item.partition("=")
            if not sep:
                raise ValueError(f"bad scenario item {item!r} "
                                 "(want key=value)")
            k = k.strip()
            if k in ("avail", "mobility"):
                d[k] = v.strip()
            elif k in ("n_classes", "seed"):
                d[k] = int(v)
            else:
                d[k] = float(v)
        return cls.from_dict(d)
