"""Synthetic closed-world corpora.

The paper fine-tunes on Alpaca (instructions) and AG-News (4-class topic
classification).  Offline we synthesize structurally equivalent corpora over
a small token vocabulary with *known latent structure*, which is what lets
reward models be trained and evaluated without human feedback:

* ``InstructionCorpus`` — instruction/response pairs.  Tokens are grouped in
  topic clusters; a HELPFUL response reuses the instruction's topic cluster;
  an unhelpful one drifts off-topic.  A designated *sensitive* token range
  models private information: responses containing it are UNSAFE.  Ground-
  truth helpfulness/safety scores are emitted with each sample (used to rank
  pairs when training the reward models, standing in for human rankers).
* ``ClassificationCorpus`` — AG-News-like: 4 classes, each with a peaked
  token distribution; documents are sampled from a class-conditional mixture.
"""
from __future__ import annotations

import dataclasses

import numpy as np

VOCAB = 512
SPECIAL = {"bos": 0, "eos": 1, "pad": 2, "instr": 3, "resp": 4, "mask": 5}
N_TOPICS = 8
TOPIC_SIZE = 48
TOPIC_BASE = 16                       # topic t owns [base+t*size, base+(t+1)*size)
SENSITIVE_RANGE = (400, 450)          # unsafe tokens


def topic_tokens(t: int) -> np.ndarray:
    lo = TOPIC_BASE + t * TOPIC_SIZE
    return np.arange(lo, lo + TOPIC_SIZE)


def helpfulness_score(instr_topic: int, response: np.ndarray) -> float:
    """Fraction of response tokens inside the instruction's topic cluster."""
    toks = topic_tokens(instr_topic)
    if len(response) == 0:
        return 0.0
    return float(np.isin(response, toks).mean())


def safety_score(response: np.ndarray) -> float:
    """1 - fraction of sensitive tokens."""
    if len(response) == 0:
        return 1.0
    lo, hi = SENSITIVE_RANGE
    return float(1.0 - ((response >= lo) & (response < hi)).mean())


@dataclasses.dataclass
class InstructionCorpus:
    seq_len: int = 64
    prompt_len: int = 16
    seed: int = 0

    def sample(self, n: int, *, topic_probs=None, helpful_p: float = 0.5,
               unsafe_p: float = 0.3, rng=None):
        """Returns dict of arrays: tokens (n, seq_len), prompt_len, topic,
        help_score, safe_score, mask (response positions)."""
        rng = rng or np.random.RandomState(self.seed)
        if topic_probs is None:
            topic_probs = np.ones(N_TOPICS) / N_TOPICS
        toks = np.full((n, self.seq_len), SPECIAL["pad"], np.int32)
        topics = rng.choice(N_TOPICS, size=n, p=topic_probs)
        helps = np.zeros(n, np.float32)
        safes = np.zeros(n, np.float32)
        mask = np.zeros((n, self.seq_len), np.float32)
        for i in range(n):
            t = topics[i]
            tt = topic_tokens(t)
            prompt = np.concatenate([
                [SPECIAL["bos"], SPECIAL["instr"]],
                rng.choice(tt, self.prompt_len - 3), [SPECIAL["resp"]]])
            resp_len = self.seq_len - self.prompt_len - 1
            helpful = rng.rand() < helpful_p
            pool = tt if helpful else topic_tokens(int(rng.choice(N_TOPICS)))
            resp = rng.choice(pool, resp_len).astype(np.int64)
            if rng.rand() < unsafe_p:
                k = max(1, resp_len // 4)
                pos_s = rng.choice(resp_len, k, replace=False)
                resp[pos_s] = rng.randint(*SENSITIVE_RANGE, size=k)
            seq = np.concatenate([prompt, resp, [SPECIAL["eos"]]])
            toks[i, :len(seq)] = seq
            mask[i, self.prompt_len:len(seq)] = 1.0
            helps[i] = helpfulness_score(t, resp)
            safes[i] = safety_score(resp)
        return {"tokens": toks, "topic": topics, "help": helps,
                "safe": safes, "mask": mask,
                "prompt_len": self.prompt_len}


@dataclasses.dataclass
class ClassificationCorpus:
    n_classes: int = 4
    seq_len: int = 32
    seed: int = 0
    skew: float = 0.55      # probability mass on the class's own cluster
    class_offset: int = 0   # classes use topics [offset, offset+n_classes)
                            # (pre-training uses a disjoint topic range so the
                            # downstream task requires genuine fine-tuning)

    def sample(self, n: int, *, class_probs=None, rng=None):
        rng = rng or np.random.RandomState(self.seed)
        if class_probs is None:
            class_probs = np.ones(self.n_classes) / self.n_classes
        labels = rng.choice(self.n_classes, size=n, p=class_probs)
        toks = np.zeros((n, self.seq_len), np.int32)
        for i in range(n):
            c = labels[i]
            own = topic_tokens(self.class_offset + c)
            other_cls = int((c + 1 + rng.randint(self.n_classes - 1))
                            % self.n_classes)
            other = topic_tokens(self.class_offset + other_cls)
            use_own = rng.rand(self.seq_len - 1) < self.skew
            body = np.where(use_own, rng.choice(own, self.seq_len - 1),
                            rng.choice(other, self.seq_len - 1))
            toks[i] = np.concatenate([[SPECIAL["bos"]], body])
        return {"tokens": toks, "label": labels.astype(np.int32)}
