from repro.data.synthetic import (  # noqa: F401
    InstructionCorpus, ClassificationCorpus, VOCAB, SPECIAL,
)
from repro.data.partition import dirichlet_partition  # noqa: F401
from repro.data.pipeline import batch_iterator  # noqa: F401
