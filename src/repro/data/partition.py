"""Non-IID client partitioning (paper §V-B.2: Dirichlet split of AG-News)."""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0):
    """Partition sample indices so each client's class distribution is a
    Dirichlet(alpha) draw.  Returns list of index arrays."""
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    out = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, chunk in enumerate(np.split(idx, cuts)):
            out[client].extend(chunk.tolist())
    return [np.asarray(sorted(v)) for v in out]


def client_topic_preferences(n_clients: int, n_topics: int, sharpness: float,
                             seed: int = 0):
    """Per-client topic distributions for the instruction corpus (each client
    concentrated on a few topics → personalized instruction data)."""
    rng = np.random.RandomState(seed)
    return rng.dirichlet([sharpness] * n_topics, size=n_clients)
