"""Minimal batching pipeline over in-memory synthetic corpora."""
from __future__ import annotations

import numpy as np


def batch_iterator(arrays: dict, batch_size: int, *, seed: int = 0,
                   drop_last: bool = True):
    """Infinite shuffled batch iterator over a dict of equal-length arrays.
    Scalar entries are passed through."""
    n = len(next(v for v in arrays.values()
                 if isinstance(v, np.ndarray) and v.ndim >= 1))
    rng = np.random.RandomState(seed)
    while True:
        order = rng.permutation(n)
        for i in range(0, n - (batch_size - 1 if drop_last else 0), batch_size):
            sel = order[i:i + batch_size]
            yield {k: (v[sel] if isinstance(v, np.ndarray) and v.ndim >= 1
                       and len(v) == n else v)
                   for k, v in arrays.items()}
