"""Whisper-base — encoder-decoder; conv/mel frontend is a STUB (input_specs
provides post-conv frame embeddings).  6 encoder + 6 decoder layers.
[arXiv:2212.04356]"""
from repro.configs.base import LK, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    stages=(
        Stage((LK("enc", "mlp"),), repeats=6, stream="encoder"),
        Stage((LK("dec", "mlp"),), repeats=6, stream="decoder"),
    ),
    act="gelu",
    norm="ln",
    pos="learned",
    max_position=524_288 + 8,  # stress shapes exceed whisper's native 448
    encoder_seq=1500,          # post-conv frames for 30s audio
    sparse_attn=SparseAttnConfig(),
    source="arXiv:2212.04356",
))
