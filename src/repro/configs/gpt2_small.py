"""GPT-2 small — the paper's own PFIT policy model. [Radford et al. 2019]"""
from repro.configs.base import LK, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="gpt2-small",
    family="dense",
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50257,
    stages=(Stage((LK("attn", "mlp"),), repeats=12),),
    act="gelu",
    norm="ln",
    pos="learned",
    max_position=1024,
    tie_embeddings=True,
    # paper: 40% sparse attention during PFIT
    sparse_attn=SparseAttnConfig(head_sparsity=0.4),
    source="Radford et al., 2019 (GPT-2)",
))
