"""Jamba-v0.1-52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887]

Deviation note (DESIGN.md §4): Jamba v0.1 uses Mamba-1 selective scan; we use
the Mamba-2 SSD formulation (matmul form) as the TPU-native equivalent.
"""
from repro.configs.base import LK, MoEConfig, ModelConfig, SSMConfig, SparseAttnConfig, Stage, register

# 8-layer repeating block: attention at position 0, mamba elsewhere; MoE on
# odd positions (every other layer → 16 MoE layers over 32).
_PATTERN = (
    LK("attn", "mlp"),
    LK("mamba", "moe"),
    LK("mamba", "mlp"),
    LK("mamba", "moe"),
    LK("mamba", "mlp"),
    LK("mamba", "moe"),
    LK("mamba", "mlp"),
    LK("mamba", "moe"),
)

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    stages=(Stage(_PATTERN, repeats=4),),  # 32 layers
    act="swiglu",
    norm="rms",
    pos="rope",
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336),
    ssm=SSMConfig(state=64, headdim=64, expand=2, chunk=256, conv_width=4),
    sparse_attn=SparseAttnConfig(),
    source="arXiv:2403.19887",
))
