"""TinyLlama-1.1B — llama2-arch small dense model. [arXiv:2401.02385]"""
from repro.configs.base import LK, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    stages=(Stage((LK("attn", "mlp"),), repeats=22),),
    act="swiglu",
    norm="rms",
    pos="rope",
    rope_theta=10_000.0,
    # Paper technique: block-sparse attention variant available → long_500k legal.
    sparse_attn=SparseAttnConfig(),
    source="arXiv:2401.02385",
))
