"""Model / shape configuration system.

Every assigned architecture is expressed as a ``ModelConfig`` built from a small
set of orthogonal pieces:

* ``Stage`` — a repeating pattern of layer kinds, scanned ``repeats`` times.
  A layer kind is ``(mixer, ff)`` where mixer ∈ {attn, local, mla, mamba, enc,
  dec} and ff ∈ {mlp, moe, none}.  Heterogeneous stacks (jamba's 1:7
  attn:mamba interleave, gemma3's 5:1 local:global, deepseek-v2's first dense
  layer) are expressed as patterns/stages so the runtime can ``lax.scan`` over
  homogeneous repeats and keep the HLO small.
* ``MoEConfig`` / ``SSMConfig`` / ``MLAConfig`` / ``SparseAttnConfig`` —
  optional feature blocks.

The four benchmark input shapes are defined here as well.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------

MIXERS = ("attn", "local", "mla", "mamba", "enc", "dec", "none")
FFS = ("mlp", "moe", "none")


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str  # attn | local | mla | mamba | enc | dec | none
    ff: str     # mlp | moe | none

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ff in FFS, self.ff

    @property
    def tag(self) -> str:
        return f"{self.mixer}:{self.ff}"


def LK(mixer: str, ff: str) -> LayerKind:
    return LayerKind(mixer, ff)


@dataclasses.dataclass(frozen=True)
class Stage:
    """``pattern`` is applied in order, the whole pattern repeated ``repeats``
    times (scan axis).  ``stream`` selects which token stream the stage runs
    on for encoder/decoder models."""

    pattern: Tuple[LayerKind, ...]
    repeats: int
    stream: str = "decoder"  # decoder | encoder

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


# ---------------------------------------------------------------------------
# Feature blocks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden width
    n_shared_experts: int = 0     # deepseek-v2 style always-on experts
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SparseAttnConfig:
    """The paper's sparse-attention device, adapted to TPU as a *static*
    block-sparse pattern: a local band + attention-sink blocks + strided
    global blocks.  ``head_sparsity`` is the fraction of attention heads whose
    parameters are masked from federated communication (paper: 40%)."""

    block_size: int = 128
    local_blocks: int = 4
    sink_blocks: int = 1
    stride: int = 8
    head_sparsity: float = 0.4


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio | encoder
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                     # dense-MLP hidden width (0 → no dense MLP anywhere)
    vocab_size: int
    stages: Tuple[Stage, ...]
    head_dim: int = 0             # 0 → d_model // n_heads
    window: int = 0               # sliding window for "local" mixers
    norm: str = "rms"             # rms | ln
    act: str = "swiglu"           # swiglu | geglu | gelu
    pos: str = "rope"             # rope | learned
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma-style sqrt(d_model) embedding scale
    max_position: int = 0         # learned-pos table size (0 → derived per run)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    sparse_attn: Optional[SparseAttnConfig] = None
    # -- modality frontend stubs -------------------------------------------
    n_prefix_tokens: int = 0      # VLM: number of patch-embedding positions
    prefix_dim: int = 0           # VLM: ViT output width (projector input)
    encoder_seq: int = 0          # audio: number of (post-conv) frames
    n_classes: int = 0            # encoder classifier head (roberta / PFTT)
    source: str = ""              # citation

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def decoder_stages(self) -> Tuple[Stage, ...]:
        return tuple(s for s in self.stages if s.stream == "decoder")

    @property
    def encoder_stages(self) -> Tuple[Stage, ...]:
        return tuple(s for s in self.stages if s.stream == "encoder")

    @property
    def is_encoder_decoder(self) -> bool:
        return bool(self.encoder_stages) and bool(self.decoder_stages)

    @property
    def is_encoder_only(self) -> bool:
        return bool(self.encoder_stages) and not self.decoder_stages

    @property
    def attention_free(self) -> bool:
        return all(
            k.mixer in ("mamba", "none")
            for s in self.stages
            for k in s.pattern
        )

    @property
    def sub_quadratic(self) -> bool:
        """True if every long-context mixer path is sub-quadratic: SSM layers,
        sliding-window layers, or block-sparse attention enabled."""
        if self.attention_free:
            return True
        for s in self.stages:
            for k in s.pattern:
                if k.mixer in ("attn", "mla", "enc", "dec") and self.sparse_attn is None:
                    return False
                if k.mixer == "local" and self.window <= 0:
                    return False
        return True

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.headdim

    def param_count(self, include_embed: bool = True) -> int:
        """Analytic parameter count (used by comm-cost accounting & roofline)."""
        from repro.models.blocks import layer_param_count  # local import, no cycle

        total = 0
        if include_embed:
            total += self.vocab_size * self.d_model
            if not self.tie_embeddings:
                total += self.vocab_size * self.d_model
            if self.pos == "learned":
                total += max(self.max_position, 4096) * self.d_model
        for s in self.stages:
            for k in s.pattern:
                total += layer_param_count(self, k) * s.repeats
        total += self.d_model  # final norm
        if self.n_prefix_tokens:
            total += self.prefix_dim * self.d_model  # VLM projector
        if self.n_classes:
            total += self.d_model * self.n_classes
        return total

    def active_param_count(self) -> int:
        """MoE-aware 'active per token' count (for MODEL_FLOPS = 6·N_active·D)."""
        from repro.models.blocks import layer_param_count

        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        for s in self.stages:
            for k in s.pattern:
                total += layer_param_count(self, k, active_only=True) * s.repeats
        total += self.d_model
        return total

    def reduced(self, d_model: int = 256, repeats: int = 1, n_experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests: ≤2 effective
        layers per stage pattern, d_model ≤ 512, ≤4 experts."""
        scale = d_model / self.d_model
        n_heads = max(2, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        hd = d_model // n_heads
        stages = []
        for s in self.stages:
            pattern = s.pattern[: min(len(s.pattern), 2)]
            stages.append(Stage(pattern, min(s.repeats, repeats), s.stream))
        moe = None
        if self.moe is not None:
            moe = MoEConfig(
                n_experts=min(self.moe.n_experts, n_experts),
                top_k=min(self.moe.top_k, 2),
                d_ff=max(32, int(self.moe.d_ff * scale)),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                capacity_factor=2.0,
            )
        ssm = None
        if self.ssm is not None:
            ssm = SSMConfig(state=16, headdim=16, expand=self.ssm.expand,
                            chunk=32, conv_width=self.ssm.conv_width)
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                            nope_head_dim=hd, v_head_dim=hd)
        sparse = self.sparse_attn
        if sparse is not None:
            sparse = SparseAttnConfig(block_size=16, local_blocks=2,
                                      sink_blocks=1, stride=4,
                                      head_sparsity=sparse.head_sparsity)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=max(32, int(self.d_ff * scale)) if self.d_ff else 0,
            vocab_size=vocab,
            stages=tuple(stages),
            window=min(self.window, 64) if self.window else 0,
            max_position=1024,
            moe=moe,
            ssm=ssm,
            mla=mla,
            sparse_attn=sparse,
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            prefix_dim=min(self.prefix_dim, 64) if self.prefix_dim else 0,
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs():
    _load_all()
    return sorted(_REGISTRY)


ASSIGNED = (
    "whisper-base", "jamba-v0.1-52b", "mamba2-1.3b", "gemma3-12b",
    "dbrx-132b", "tinyllama-1.1b", "llama3.2-1b", "deepseek-67b",
    "internvl2-26b", "deepseek-v2-236b",
)

PAPER_OWN = ("gpt2-small", "roberta-base")


def _load_all():
    # import side effects register the configs
    from repro.configs import (  # noqa: F401
        whisper_base, jamba_v0_1_52b, mamba2_1_3b, gemma3_12b, dbrx_132b,
        tinyllama_1_1b, llama3_2_1b, deepseek_67b, internvl2_26b,
        deepseek_v2_236b, gpt2_small, roberta_base,
    )
