"""DeepSeek-V2-236B — MLA (kv_lora=512) + fine-grained MoE, 2 shared + 160
routed experts top-6.  First layer uses a dense FF (separate prologue stage).
[arXiv:2405.04434]"""
from repro.configs.base import LK, MLAConfig, MoEConfig, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,       # MLA: effectively MHA over the compressed cache
    head_dim=128,
    d_ff=12288,           # dense FF width for the first (non-MoE) layer
    vocab_size=102400,
    stages=(
        Stage((LK("mla", "mlp"),), repeats=1),
        Stage((LK("mla", "moe"),), repeats=59),
    ),
    act="swiglu",
    norm="rms",
    pos="rope",
    moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536, n_shared_experts=2),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    sparse_attn=SparseAttnConfig(),
    source="arXiv:2405.04434",
))
