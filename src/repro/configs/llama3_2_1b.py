"""Llama-3.2-1B — small llama3 dense model. [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import LK, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="llama3.2-1b",
    family="dense",
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    stages=(Stage((LK("attn", "mlp"),), repeats=16),),
    act="swiglu",
    norm="rms",
    pos="rope",
    rope_theta=500_000.0,
    tie_embeddings=True,
    sparse_attn=SparseAttnConfig(),
    source="hf:meta-llama/Llama-3.2-1B",
))
