"""Mamba2-1.3B — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060]"""
from repro.configs.base import LK, ModelConfig, SSMConfig, Stage, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    d_model=2048,
    n_heads=0,            # attention-free
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,               # mamba2 blocks have no separate MLP
    vocab_size=50280,
    stages=(Stage((LK("mamba", "none"),), repeats=48),),
    norm="rms",
    pos="rope",           # unused by mamba mixer; kept for embedding path
    tie_embeddings=True,
    ssm=SSMConfig(state=128, headdim=64, expand=2, chunk=256, conv_width=4),
    source="arXiv:2405.21060",
))
