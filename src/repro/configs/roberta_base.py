"""RoBERTa-base — the paper's own PFTT backbone (encoder-only classifier,
AG-News 4 classes). [arXiv:1907.11692]"""
from repro.configs.base import LK, ModelConfig, Stage, register

CONFIG = register(ModelConfig(
    name="roberta-base",
    family="encoder",
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50265,
    stages=(Stage((LK("enc", "mlp"),), repeats=12, stream="encoder"),),
    act="gelu",
    norm="ln",
    pos="learned",
    max_position=514,
    n_classes=4,  # AG-News
    source="arXiv:1907.11692",
))
