"""DBRX-132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
from repro.configs.base import LK, MoEConfig, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,          # per-expert hidden width
    vocab_size=100352,
    stages=(Stage((LK("attn", "moe"),), repeats=40),),
    act="swiglu",
    norm="ln",
    pos="rope",
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff=10752),
    sparse_attn=SparseAttnConfig(),
    source="hf:databricks/dbrx-base",
))
