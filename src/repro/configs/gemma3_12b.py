"""Gemma3-12B — dense, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt (family card, 12B point in the series)]"""
from repro.configs.base import LK, ModelConfig, SparseAttnConfig, Stage, register

_PATTERN = (LK("local", "mlp"),) * 5 + (LK("attn", "mlp"),)

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=240,
    d_ff=15360,
    vocab_size=262144,
    stages=(Stage(_PATTERN, repeats=8),),  # 48 layers
    window=1024,
    act="geglu",
    norm="rms",
    pos="rope",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale=True,
    sparse_attn=SparseAttnConfig(),  # applied to the global layers for long ctx
    source="hf:google/gemma-3-1b-pt",
))
