"""InternVL2-26B — VLM: InternViT-6B (STUB) + InternLM2-20B language decoder.
input_specs provides 256 patch embeddings at ViT width 3200; the trainable
projector maps them to d_model.  [arXiv:2404.16821]"""
from repro.configs.base import LK, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    stages=(Stage((LK("attn", "mlp"),), repeats=48),),
    act="swiglu",
    norm="rms",
    pos="rope",
    rope_theta=1_000_000.0,
    n_prefix_tokens=256,
    prefix_dim=3200,
    sparse_attn=SparseAttnConfig(),
    source="arXiv:2404.16821",
))
