"""DeepSeek-67B — llama-arch large dense model. [arXiv:2401.02954]"""
from repro.configs.base import LK, ModelConfig, SparseAttnConfig, Stage, register

CONFIG = register(ModelConfig(
    name="deepseek-67b",
    family="dense",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    stages=(Stage((LK("attn", "mlp"),), repeats=95),),
    act="swiglu",
    norm="rms",
    pos="rope",
    rope_theta=10_000.0,
    sparse_attn=SparseAttnConfig(),
    source="arXiv:2401.02954",
))
